"""BSTEngine: strategy equivalence + paper-preset behaviour."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import BSTEngine, EngineConfig, PAPER_CONFIGS
from repro.data.keysets import make_key_sets, make_tree_data


@pytest.fixture(scope="module")
def engines():
    keys, values = make_tree_data(2047, seed=3)
    return {
        name: BSTEngine(keys, values, cfg) for name, cfg in PAPER_CONFIGS.items()
    }, keys, values


def test_all_strategies_equivalent(engines):
    engs, keys, values = engines
    rng = np.random.default_rng(0)
    q = rng.choice(np.concatenate([keys, keys + 1]), size=1024).astype(np.int32)
    ref = None
    for name, eng in engs.items():
        v, f = eng.lookup(q)
        v, f = np.asarray(v), np.asarray(f)
        if ref is None:
            ref = (v, f)
        assert np.array_equal(v, ref[0]), name
        assert np.array_equal(f, ref[1]), name


def test_found_values_correct(engines):
    engs, keys, values = engines
    kv = dict(zip(keys.tolist(), values.tolist()))
    rng = np.random.default_rng(1)
    q = rng.choice(keys, 512).astype(np.int32)
    v, f = engs["Hyb8q"].lookup(q)
    assert bool(np.all(np.asarray(f)))
    for qi, vi in zip(q.tolist(), np.asarray(v).tolist()):
        assert kv[qi] == vi


def test_memory_accounting(engines):
    engs, *_ = engines
    base = engs["Hrz"].memory_nodes()
    assert engs["Dup4"].memory_nodes() == 4 * base
    assert engs["Dup8"].memory_nodes() == 8 * base
    assert engs["Hyb8"].memory_nodes() == base  # no duplication (paper Fig.8)


@given(
    st.integers(10, 400),
    st.sampled_from(["Hrz", "Dup4", "Hyb4", "Hyb8q"]),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_engine_property_random_trees(n_keys, impl, seed):
    keys, values = make_tree_data(n_keys, seed=seed % 1000)
    eng = BSTEngine(keys, values, PAPER_CONFIGS[impl])
    rng = np.random.default_rng(seed % 2**31)
    q = rng.choice(np.concatenate([keys, keys + 1]), size=128).astype(np.int32)
    v, f = eng.lookup(q)
    kv = dict(zip(keys.tolist(), values.tolist()))
    for qi, vi, fi in zip(q.tolist(), np.asarray(v).tolist(), np.asarray(f).tolist()):
        if qi in kv:
            assert fi and vi == kv[qi]
        else:
            assert not fi


def test_kernel_backed_engine_matches(engines):
    """use_kernel=True routes descent through the Pallas kernel."""
    _, keys, values = engines
    rng = np.random.default_rng(5)
    q = rng.choice(np.concatenate([keys, keys + 1]), size=512).astype(np.int32)
    ref_v, ref_f = BSTEngine(keys, values, EngineConfig(strategy="hrz")).lookup(q)
    for cfg in (
        EngineConfig(strategy="hrz", use_kernel=True),
        EngineConfig(strategy="hyb", n_trees=4, mapping="queue", use_kernel=True),
    ):
        v, f = BSTEngine(keys, values, cfg).lookup(q)
        assert np.array_equal(np.asarray(v), np.asarray(ref_v)), cfg.name
        assert np.array_equal(np.asarray(f), np.asarray(ref_f)), cfg.name
