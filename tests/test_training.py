"""Training substrate: optimization, accumulation, compression, pipeline."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import TokenPipeline
from repro.optim import optimizer as opt
from repro.training.train_loop import (
    TrainConfig,
    init_train_state,
    make_train_step,
    train_loop,
)


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("tinyllama_1p1b")


def test_loss_decreases_over_steps(cfg):
    """Memorize one fixed batch: loss must fall well below the ln(V) floor
    of the uniform synthetic stream."""
    import dataclasses as dc

    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=2, total_steps=30)

    class FixedBatch:
        def __init__(self, pipe):
            self._b = pipe.batch_at(0)

        def batch_at(self, step):
            return self._b

    pipe = FixedBatch(TokenPipeline(cfg.vocab_size, seq_len=32, global_batch=4, seed=0))
    state, hist = train_loop(cfg, tcfg, pipe, steps=25)
    first = hist[0]["loss"]
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, (first, last)
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_grad_accumulation_equivalence(cfg):
    """microbatches=4 must equal microbatches=1 on the same global batch."""
    pipe = TokenPipeline(cfg.vocab_size, seq_len=16, global_batch=8, seed=1)
    tokens, labels = pipe.batch_at(0)
    outs = {}
    for mb in (1, 4):
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10, microbatches=mb)
        state = init_train_state(cfg, tcfg, jax.random.key(0))
        step = make_train_step(cfg, tcfg)
        new_state, metrics = step(state, jnp.asarray(tokens), jnp.asarray(labels))
        outs[mb] = (new_state.params, metrics)
    p1 = jax.tree.leaves(outs[1][0])
    p4 = jax.tree.leaves(outs[4][0])
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3, rtol=5e-2
        )


def test_adamw_reference_behaviour():
    params = {"w": jnp.ones((4,)) * 2.0}
    state = opt.adamw_init(params)
    grads = {"w": jnp.ones((4,))}
    p1, state = opt.adamw_update(
        grads, state, jnp.asarray(0.1), weight_decay=0.0, compute_dtype=jnp.float32
    )
    # first Adam step moves by ~lr in the gradient direction
    np.testing.assert_allclose(np.asarray(p1["w"]), 2.0 - 0.1, atol=1e-3)
    # weight decay pulls toward zero
    p2, _ = opt.adamw_update(
        grads, opt.adamw_init(params), jnp.asarray(0.1),
        weight_decay=1.0, compute_dtype=jnp.float32,
    )
    assert float(p2["w"][0]) < float(p1["w"][0])


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 100.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(gn) > 100


def test_cosine_schedule_shape():
    lrs = [float(opt.cosine_schedule(jnp.asarray(s), 1.0, 10, 100)) for s in range(100)]
    # warmup counts from 1 so step 0 moves; peak reached at step warmup-1
    assert abs(lrs[0] - 0.1) < 1e-6 and abs(lrs[9] - 1.0) < 0.01
    assert lrs[99] < 0.2 and all(l > 0 for l in lrs)
    assert all(b <= a + 1e-6 for a, b in zip(lrs[10:], lrs[11:]))  # decays


def test_pipeline_stateless_resume():
    pipe = TokenPipeline(1000, seq_len=8, global_batch=4, seed=42)
    a = pipe.batch_at(17)
    b = pipe.batch_at(17)
    np.testing.assert_array_equal(a[0], b[0])
    # host sharding: different hosts, different data; union is deterministic
    p0 = TokenPipeline(1000, 8, 4, seed=42, host_index=0, host_count=2)
    p1 = TokenPipeline(1000, 8, 4, seed=42, host_index=1, host_count=2)
    assert p0.local_batch == 2
    assert not np.array_equal(p0.batch_at(3)[0], p1.batch_at(3)[0])


def test_labels_are_shifted_tokens():
    pipe = TokenPipeline(1000, seq_len=8, global_batch=2, seed=0)
    toks, labs = pipe.batch_at(0)
    np.testing.assert_array_equal(toks[:, 1:], labs[:, :-1])


def test_int8_compression_roundtrip_error_feedback():
    """Compression hook: quantization error is carried, not lost."""
    from repro.training.train_loop import _compress_grads

    # single-device psum == identity, so test the quantization mechanics
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import make_mesh, shard_map

    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray([0.1, -0.01, 0.5, 0.003], jnp.float32)}
    ef = {"w": jnp.zeros((4,), jnp.float32)}

    def run(g, ef):
        return _compress_grads(g, ef, "int8", ("data",))

    out, new_ef = jax.jit(
        shard_map(run, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                  check=False)
    )(g, ef)
    # dequantized + error ~= original
    np.testing.assert_allclose(
        np.asarray(out["w"]) + np.asarray(new_ef["w"]), np.asarray(g["w"]), atol=1e-6
    )
