"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tree as T
from repro.data.keysets import make_tree_data
from repro.kernels import ops, ref


# ------------------------------------------------------------------ bst_search
@pytest.mark.parametrize("n_keys", [1, 7, 100, 4095])
@pytest.mark.parametrize("n_queries", [1, 64, 700])
def test_bst_search_shape_sweep(n_keys, n_queries):
    keys, values = make_tree_data(n_keys, seed=n_keys)
    tree = T.build_tree(keys, values)
    rng = np.random.default_rng(n_queries)
    q = rng.choice(np.concatenate([keys, keys + 1]), size=n_queries).astype(np.int32)
    v1, f1 = ops.bst_search(tree.keys, tree.values, jnp.asarray(q), height=tree.height)
    v2, f2 = ref.bst_search_ref(tree.keys, tree.values, jnp.asarray(q), tree.height)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


@pytest.mark.parametrize("register_levels", [1, 2, 5])
@pytest.mark.parametrize("block_q", [32, 512])
def test_bst_search_config_sweep(register_levels, block_q, medium_tree):
    tree, keys, _ = medium_tree
    rng = np.random.default_rng(0)
    q = rng.choice(np.concatenate([keys, keys + 1]), size=333).astype(np.int32)
    act = jnp.asarray(rng.integers(0, 2, size=333).astype(bool))
    v1, f1 = ops.bst_search(
        tree.keys, tree.values, jnp.asarray(q), height=tree.height,
        active=act, register_levels=register_levels, block_q=block_q,
    )
    v2, f2 = ref.bst_search_ref(tree.keys, tree.values, jnp.asarray(q), tree.height, act)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


@given(st.integers(1, 300), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_bst_search_property(n_keys, seed):
    keys, values = make_tree_data(n_keys, seed=seed)
    tree = T.build_tree(keys, values)
    rng = np.random.default_rng(seed)
    q = rng.choice(np.concatenate([keys, keys + 1]), size=97).astype(np.int32)
    v1, f1 = ops.bst_search(tree.keys, tree.values, jnp.asarray(q), height=tree.height)
    v2, f2 = ref.bst_search_ref(tree.keys, tree.values, jnp.asarray(q), tree.height)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


# -------------------------------------------------------------- queue_dispatch
@pytest.mark.parametrize("n_dest,capacity,size", [
    (2, 2, 16), (8, 16, 128), (16, 8, 64), (4, 1, 33),
])
def test_queue_dispatch_sweep(n_dest, capacity, size):
    rng = np.random.default_rng(size)
    dest = jnp.asarray(rng.integers(-1, n_dest, size=size).astype(np.int32))
    b1, c1, o1 = ops.queue_dispatch(dest, n_dest=n_dest, capacity=capacity)
    b2, c2, o2 = ref.queue_dispatch_ref(dest, n_dest, capacity)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def _numpy_queue_model(dest, n_dest, capacity):
    """Independent NumPy model of the paper's queue mapping (Fig. 6): label
    each key with the count of earlier same-destination keys, keep it iff
    the label fits the buffer, preserve FIFO order."""
    buffers = np.full((n_dest, capacity), -1, np.int64)
    counts = np.zeros(n_dest, np.int64)
    overflow = np.zeros(len(dest), bool)
    for i, d in enumerate(dest):
        if d < 0:
            continue
        if counts[d] < capacity:
            buffers[d, counts[d]] = i
            counts[d] += 1
        else:
            overflow[i] = True
    return buffers, counts, overflow


@pytest.mark.parametrize("skew", ["all_one_dest", "two_hot", "mixed_inactive"])
def test_queue_dispatch_overflow_lanes(skew):
    """Force buffer overflow and pin the overflow_ref path of the Pallas
    kernel (and the jnp oracle) against the NumPy model: overflowed lanes
    must be flagged, NEVER placed in any buffer slot, and never counted."""
    n_dest, capacity, size = 4, 3, 40
    rng = np.random.default_rng(17)
    if skew == "all_one_dest":
        dest = np.zeros(size, np.int32)  # every lane overflows past slot 2
    elif skew == "two_hot":
        dest = rng.choice(np.array([1, 2], np.int32), size)
    else:  # inactive lanes interleaved with a hot destination
        dest = rng.choice(np.array([-1, 0, 0, 0, 3], np.int32), size)
    b_np, c_np, o_np = _numpy_queue_model(dest, n_dest, capacity)
    assert o_np.any(), "scenario must actually overflow"

    for use_ref in (False, True):
        b, c, o = ops.queue_dispatch(
            jnp.asarray(dest), n_dest=n_dest, capacity=capacity, use_ref=use_ref
        )
        tag = f"use_ref={use_ref}"
        np.testing.assert_array_equal(np.asarray(b), b_np, err_msg=tag)
        np.testing.assert_array_equal(np.asarray(c), c_np, err_msg=tag)
        np.testing.assert_array_equal(np.asarray(o), o_np, err_msg=tag)
        placed = np.asarray(b).reshape(-1)
        placed = set(placed[placed >= 0].tolist())
        # disjointness: a lane is either buffered or overflowed, never both
        assert placed.isdisjoint(np.flatnonzero(o_np).tolist()), tag
        kept = ~o_np & (dest >= 0)
        assert placed == set(np.flatnonzero(kept).tolist()), tag
        assert int(np.asarray(c).sum()) == int(kept.sum()), tag


# ------------------------------------------------------------- flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,BHkv,Sq,Skv,d,causal,window", [
    (4, 2, 256, 256, 64, True, None),   # GQA causal
    (4, 4, 128, 256, 32, True, None),   # decode-style offset
    (2, 1, 256, 256, 64, True, 128),    # sliding window
    (8, 2, 128, 128, 128, False, None), # bidirectional (encoder)
    (2, 2, 384, 384, 64, True, 256),    # window > block
])
def test_flash_attention_sweep(dtype, BH, BHkv, Sq, Skv, d, causal, window):
    kq = jax.random.normal(jax.random.key(0), (BH, Sq, d), jnp.float32).astype(dtype)
    kk = jax.random.normal(jax.random.key(1), (BHkv, Skv, d), jnp.float32).astype(dtype)
    kv = jax.random.normal(jax.random.key(2), (BHkv, Skv, d), jnp.float32).astype(dtype)
    o1 = ops.flash_attention(kq, kk, kv, causal=causal, window=window)
    o2 = ops.flash_attention(kq, kk, kv, causal=causal, window=window, use_ref=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), atol=tol, rtol=tol
    )


def test_flash_matches_blockwise_impl():
    """The jnp blockwise path (used in dry-runs) == the Pallas kernel."""
    from repro.models.attention import _blockwise_attn

    B, Sq, H, KV, hd = 2, 256, 4, 2, 64
    q = jax.random.normal(jax.random.key(0), (B, Sq, H, hd))
    k = jax.random.normal(jax.random.key(1), (B, Sq, KV, hd))
    v = jax.random.normal(jax.random.key(2), (B, Sq, KV, hd))
    blockwise = _blockwise_attn(q, k, v, True, None, 64, hd**-0.5)
    qf = q.swapaxes(1, 2).reshape(B * H, Sq, hd)
    kf = k.swapaxes(1, 2).reshape(B * KV, Sq, hd)
    vf = v.swapaxes(1, 2).reshape(B * KV, Sq, hd)
    flash = ops.flash_attention(qf, kf, vf, causal=True)
    flash = flash.reshape(B, H, Sq, hd).swapaxes(1, 2)
    np.testing.assert_allclose(
        np.asarray(blockwise), np.asarray(flash), atol=1e-5, rtol=1e-5
    )
