"""Multi-device tests (8 fake CPU devices, subprocess-isolated).

The XLA device-count flag must be set before jax initializes, and the main
test process must keep its single real device (smoke tests measure real
behaviour), so every case here runs in a subprocess -- the shared runner
lives in ``conftest.run_forced_multi_device`` (also the ``multi_device_host``
fixture the sharded differential suite uses).
"""

from conftest import run_forced_multi_device


def run_sub(body: str, devices: int = 8, timeout: int = 1200) -> str:
    return run_forced_multi_device(body, devices=devices, timeout=timeout)


def test_distributed_bst_lookup_vertical_partitioning():
    out = run_sub("""
        from repro.core import tree as T
        from repro.core.distributed import make_distributed_lookup, make_dup_lookup
        from repro.data.keysets import make_tree_data
        mesh = make_mesh((2, 4), ("data", "model"))
        keys, values = make_tree_data(4000)
        tr = T.build_tree(keys, values)
        rng = np.random.default_rng(0)
        q = rng.choice(np.concatenate([keys, keys + 1]), size=256).astype(np.int32)
        ref_v, ref_f = T.search_reference(tr, jnp.asarray(q))
        with mesh:
            for kw in (dict(), dict(capacity=48, stall_rounds=2)):
                look = make_distributed_lookup(tr, mesh, axis="model", **kw)
                v, f = look(q)
                assert np.array_equal(np.asarray(v), np.asarray(ref_v)), kw
                assert np.array_equal(np.asarray(f), np.asarray(ref_f)), kw
            dup = make_dup_lookup(tr, mesh, axis="data")
            v, f = dup(q)
            assert np.array_equal(np.asarray(v), np.asarray(ref_v))
        print("OK")
    """)
    assert "OK" in out


def test_distributed_ordered_query_ops():
    """query(op, ...) over the all_to_all engine and the DP engine matches
    the NumPy searchsorted oracle for every ordered op (DESIGN.md §6)."""
    out = run_sub("""
        from repro.core import tree as T
        from repro.core.distributed import make_distributed_query, make_dup_query
        from repro.data.keysets import make_tree_data
        mesh = make_mesh((2, 4), ("data", "model"))
        keys, values = make_tree_data(4000)
        tr = T.build_tree(keys, values)
        sk = np.sort(np.asarray(keys))
        rng = np.random.default_rng(0)
        q = rng.choice(np.concatenate([keys, keys + 1, [1]]), size=256).astype(np.int32)
        lo = rng.choice(keys, 256).astype(np.int32)
        hi = (lo + rng.integers(-5, 500, size=256)).astype(np.int32)
        exp_cnt = (np.searchsorted(sk, hi, 'right') - np.searchsorted(sk, lo, 'left')).clip(0)
        i = np.searchsorted(sk, q, 'right') - 1
        exp_pk = np.where(i >= 0, sk[np.clip(i, 0, None)], T.NO_PRED_KEY)
        start = np.searchsorted(sk, lo, 'left')
        with mesh:
            runs = [make_distributed_query(tr, mesh, axis="model"),
                    make_distributed_query(tr, mesh, axis="model", capacity=48, stall_rounds=2),
                    make_dup_query(tr, mesh, axis="data")]
            for run in runs:
                pk, pv, ok = run("predecessor", q)
                assert np.array_equal(np.asarray(pk), exp_pk)
                assert np.array_equal(np.asarray(ok), i >= 0)
                cnt = run("range_count", lo, hi)
                assert np.array_equal(np.asarray(cnt), exp_cnt)
                K, V, tk = run("range_scan", lo, hi, k=4)
                assert np.array_equal(np.asarray(tk), np.minimum(exp_cnt, 4))
                for j in range(0, 256, 41):
                    t = int(np.asarray(tk)[j])
                    assert np.array_equal(np.asarray(K)[j, :t], sk[start[j]:start[j] + t]), j
                print("engine ok")
            # adversarial skew: every key routes to ONE subtree, tiny buffers,
            # no stall rounds -- the final drain round must keep ranks exact.
            skew = (np.full(256, sk[10]) + np.arange(256) % 3).astype(np.int32)
            run = make_distributed_query(tr, mesh, axis="model", capacity=2, stall_rounds=0)
            pk, pv, ok = run("predecessor", skew)
            i = np.searchsorted(sk, skew, 'right') - 1
            assert np.array_equal(np.asarray(pk), sk[i])
            cnt = run("range_count", skew, skew + 100)
            exp = np.searchsorted(sk, skew + 100, 'right') - np.searchsorted(sk, skew, 'left')
            assert np.array_equal(np.asarray(cnt), exp)
            print("overflow drain ok")
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_distributed_delta_write_path():
    """run(op, ..., delta=...) on both multi-chip engines: the replicated
    write buffer (DESIGN.md §7) folds into the packed OrderedResult after
    the collectives -- lookup/predecessor/range ops must all match a
    dict+sorted oracle, with upserts, overwrites and tombstones live."""
    out = run_sub("""
        import bisect
        from repro.core import build_tree, delta as D, tree as T
        from repro.core.distributed import make_distributed_query, make_dup_query
        from repro.data.keysets import make_tree_data
        mesh = make_mesh((2, 4), ("data", "model"))
        keys, values = make_tree_data(4000)
        tr = build_tree(keys, values)
        kv = dict(zip(keys.tolist(), values.tolist()))
        # buffer: new key, overwrite, tombstone (and a tombstone-miss no-op)
        nk = np.array([3, int(keys[7]), int(keys[50]), 9999999], np.int32)
        nv = np.array([30, 777, 0, 0], np.int32)
        nd = np.array([False, False, True, True])
        res = T.search_reference_ordered(tr, jnp.asarray(nk))
        d = D.ingest(D.empty(16), jnp.asarray(nk), jnp.asarray(nv),
                     jnp.asarray(nd), jnp.ones(4, bool), res.found, res.rank)
        kv[3] = 30; kv[int(keys[7])] = 777; kv.pop(int(keys[50]))
        sk = sorted(kv)
        rng = np.random.default_rng(1)
        q = np.concatenate([nk, rng.choice(np.concatenate([keys, keys + 1]), 248)]).astype(np.int32)
        with mesh:
            for run in (make_distributed_query(tr, mesh, axis="model"),
                        make_dup_query(tr, mesh, axis="data")):
                v, f = run("lookup", q, delta=d)
                pk, pv, ok = run("predecessor", q, delta=d)
                cnt = run("range_count", q, q + 60, delta=d)
                K, V, tk = run("range_scan", q, q + 60, k=4, delta=d)
                for i, qq in enumerate(q.tolist()):
                    assert bool(f[i]) == (qq in kv), qq
                    if qq in kv: assert int(v[i]) == kv[qq], qq
                    j = bisect.bisect_right(sk, qq) - 1
                    if j >= 0:
                        assert bool(ok[i]) and int(pk[i]) == sk[j], qq
                        assert int(pv[i]) == kv[sk[j]], qq
                    else:
                        assert not bool(ok[i])
                    in_r = [x for x in sk if qq <= x <= qq + 60]
                    assert int(cnt[i]) == len(in_r), qq
                    t = int(np.asarray(tk)[i])
                    assert t == min(len(in_r), 4)
                    assert np.asarray(K)[i, :t].tolist() == in_r[:t], qq
                # the same handle without delta still answers from the snapshot
                v0, f0 = run("lookup", np.full(8, 3, np.int32))
                assert not bool(f0[0])
                print("engine ok")
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_pjit_train_step_all_families_small_mesh():
    """Every family's sharded train step lowers AND runs on a (2,2,2) mesh."""
    out = run_sub("""
        import dataclasses
        from repro.configs import smoke_config
        from repro.training.train_loop import TrainConfig, init_train_state, make_train_step
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch in ("tinyllama_1p1b", "mixtral_8x7b", "mamba2_1p3b",
                     "hymba_1p5b", "seamless_m4t_medium", "internvl2_2b"):
            cfg = smoke_config(arch)
            cfg = dataclasses.replace(cfg, d_model=64, head_dim=16)
            tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=1, total_steps=5)
            with mesh:
                state = init_train_state(cfg, tcfg, jax.random.key(0))
                from repro.checkpoint.elastic import reshard_state
                state = reshard_state(state, cfg, mesh)
                step = make_train_step(cfg, tcfg, mesh=mesh, mode="pjit")
                toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
                labs = jax.random.randint(jax.random.key(2), (8, 32), 0, cfg.vocab_size)
                args = (state, toks, labs)
                if cfg.frontend is not None:
                    flen = 32 if cfg.family == "encdec" else cfg.frontend_len
                    fe = jnp.zeros((8, flen, cfg.d_model), cfg.param_dtype)
                    args = args + (fe,)
                state2, metrics = step(*args)
                assert np.isfinite(float(metrics["loss"])), arch
                print("ok", arch, float(metrics["loss"]))
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_dp_shard_map_compression_modes():
    """Pure-DP step with bf16/int8 compressed all-reduce converges the same."""
    out = run_sub("""
        from repro.configs import smoke_config
        from repro.data.pipeline import TokenPipeline
        from repro.training.train_loop import TrainConfig, init_train_state, make_train_step
        mesh = make_mesh((8,), ("data",))
        cfg = smoke_config("tinyllama_1p1b")
        pipe = TokenPipeline(cfg.vocab_size, 16, 8, seed=3)
        losses = {}
        for comp in (None, "bf16", "int8"):
            tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=1, total_steps=12,
                               compression=comp)
            with mesh:
                state = init_train_state(cfg, tcfg, jax.random.key(0))
                step = make_train_step(cfg, tcfg, mesh=mesh, mode="dp_shard_map")
                for s in range(10):
                    tokens, labels = pipe.batch_at(s)
                    state, m = step(state, jnp.asarray(tokens), jnp.asarray(labels), None)
                losses[comp] = float(m["loss"])
        print("losses", losses)
        base = losses[None]
        # compressed runs must track the uncompressed one; absolute floor is
        # ln(vocab)=6.22 for uniform synthetic tokens
        assert all(abs(v - base) < 0.35 for v in losses.values()), losses
        assert all(v < 6.5 for v in losses.values()), losses
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_elastic_reshard_across_mesh_shapes():
    """Checkpoint under a (4,2) mesh, restore under (2,2) and (8,1): the
    surviving-slice restart path."""
    out = run_sub("""
        import tempfile
        from repro.configs import smoke_config
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.checkpoint.elastic import reshard_state
        from repro.training.train_loop import TrainConfig, init_train_state, make_train_step
        cfg = smoke_config("tinyllama_1p1b")
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=1, total_steps=5)
        d = tempfile.mkdtemp()
        mesh_a = make_mesh((4, 2), ("data", "model"))
        with mesh_a:
            state = reshard_state(init_train_state(cfg, tcfg, jax.random.key(0)), cfg, mesh_a)
            step = make_train_step(cfg, tcfg, mesh=mesh_a, mode="pjit", donate=False)
            toks = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab_size)
            labs = jax.random.randint(jax.random.key(2), (8, 16), 0, cfg.vocab_size)
            state, m0 = step(state, toks, labs)
            save_checkpoint(d, 0, state)
        for shape, axes in (((2, 2), ("data", "model")), ((8,), ("data",))):
            mesh_b = make_mesh(shape, axes)
            with mesh_b:
                like = init_train_state(cfg, tcfg, jax.random.key(0))
                restored, _, _ = restore_checkpoint(d, like)
                restored = reshard_state(restored, cfg, mesh_b)
                step_b = make_train_step(cfg, tcfg, mesh=mesh_b, mode="pjit", donate=False)
                state2, m = step_b(restored, toks, labs)
                assert np.isfinite(float(m["loss"]))
                print("resharded ok", shape, float(m["loss"]))
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_perf_sharding_variants_run_correctly():
    """seq-sharded decode cache / dp_only / zero1 are sharding-only changes:
    they must produce the SAME numbers as the unsharded step."""
    out = run_sub("""
        import dataclasses
        from repro.configs import smoke_config
        from repro.models import model as M
        from repro.serving.serve_loop import make_serve_step
        from repro.checkpoint.elastic import reshard_state
        from repro.training.train_loop import TrainConfig, init_train_state, make_train_step
        from repro.sharding import specs as SP
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config("qwen3_1p7b")
        params = M.init_params(cfg, jax.random.key(0))
        B, S = 8, 16  # dp_only requires global_batch % device_count == 0
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        logits_ref, state = M.prefill(cfg, params, toks, max_len=S + 4)
        nxt = jnp.argmax(logits_ref, -1)[:, None].astype(jnp.int32)
        ref_logits, _ = M.decode_step(cfg, params, nxt, state)
        # serve steps donate the cache: keep a host copy to rebuild from
        state_host = jax.tree.map(lambda a: np.asarray(a), state)

        with mesh:
            for seq_shard in (False, True):
                step = make_serve_step(cfg, mesh=mesh, batch=B, seq_shard=seq_shard)
                cache = jax.device_put(
                    jax.tree.map(jnp.asarray, state_host),
                    SP._named(mesh, SP.decode_state_specs(cfg, mesh, B, seq_shard=seq_shard)))
                lg, _ = step(params, nxt, cache)
                assert np.allclose(np.asarray(lg), np.asarray(ref_logits), atol=2e-4), seq_shard
                print("serve seq_shard", seq_shard, "ok")

        # dp_only + zero1 train step matches the unsharded step
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=5)
        labs = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
        st0 = init_train_state(cfg, tcfg, jax.random.key(0))
        ref_state, ref_m = make_train_step(cfg, tcfg, donate=False)(st0, toks, labs)
        for variant in ({"sharding_strategy": "dp_only"},
                        {"sharding_strategy": "dp_only", "zero1": True},
                        {"zero1": True}):
            cfg2 = dataclasses.replace(cfg, **variant)
            with mesh:
                st = reshard_state(init_train_state(cfg2, tcfg, jax.random.key(0)), cfg2, mesh)
                step = make_train_step(cfg2, tcfg, mesh=mesh, mode="pjit", donate=False)
                st2, m = step(st, toks, labs)
                assert abs(float(m["loss"]) - float(ref_m["loss"])) < 1e-4, variant
                print("train", variant, "ok", float(m["loss"]))
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_dryrun_cell_smoke_8dev():
    """launch/dryrun machinery end-to-end on a tiny arch at 8 devices."""
    out = run_sub("""
        import dataclasses
        from repro.configs import smoke_config
        from repro.models.config import SHAPES
        from repro.launch import dryrun as DR
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = smoke_config("qwen3_1p7b")
        cfg = dataclasses.replace(cfg, dtype="bfloat16", attention_impl="blockwise",
                                  remat=True, logit_chunk=16)
        for sname, seq, gb in (("train_4k", 64, 8), ("prefill_32k", 64, 8), ("decode_32k", 64, 8)):
            shape = dataclasses.replace(SHAPES[sname], seq_len=seq, global_batch=gb)
            c = DR.build_lowered(cfg, shape, mesh).compile()
            cb = DR.collective_bytes(c.as_text())
            assert cb["total_count"] > 0, sname
            print(sname, "collectives", cb["total_bytes"])
        print("ALL OK")
    """)
    assert "ALL OK" in out
