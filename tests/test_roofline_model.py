"""Validate the analytic FLOPs model against HLO-exact counts.

XLA cost_analysis counts while-loop (scan) bodies once, so the roofline uses
an analytic model (benchmarks/analytic_model.py).  Here we cross-validate it
on configurations where the HLO *is* exact: layers unrolled, naive
attention (no kv scan), single logit chunk, single SSD chunk, no remat.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from benchmarks.analytic_model import cell_cost
from repro.configs import smoke_config
from repro.models import model as M
from repro.models.config import SHAPES


def _exact_cfg(arch, B, S):
    cfg = smoke_config(arch)
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=128,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
        attention_impl="naive",
        remat=False,
        scan_layers=False,
        logit_chunk=S,
        ssm_chunk=S,
        sliding_window=None,
        frontend_len=0,
        frontend=None if cfg.family == "vlm" else cfg.frontend,
    )


@pytest.mark.parametrize("arch", ["tinyllama_1p1b", "mixtral_8x7b", "mamba2_1p3b"])
def test_analytic_flops_matches_unrolled_hlo(arch):
    B, S = 2, 64
    cfg = _exact_cfg(arch, B, S)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B)

    def fwd_loss(params, tokens, labels):
        loss, _ = M.forward_train(cfg, params, tokens, labels, None)
        return loss

    params = M.init_params(cfg, jax.random.key(0))
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
    grad_fn = jax.jit(jax.grad(fwd_loss))
    compiled = grad_fn.lower(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params),
        tokens, labels,
    ).compile()
    cost_analysis = compiled.cost_analysis()
    if isinstance(cost_analysis, (list, tuple)):  # jax<=0.4.x: one dict/device
        cost_analysis = cost_analysis[0]
    hlo_flops = cost_analysis["flops"]

    cost = cell_cost(cfg, shape)
    # analytic counts fwd+2x bwd matmuls only (remat off); HLO adds
    # elementwise/softmax work -> HLO should be >= analytic and within 2x
    ratio = hlo_flops / cost.flops
    assert 0.6 < ratio < 2.0, (arch, hlo_flops, cost.flops, ratio)


def test_unrolled_matches_scanned_numerics():
    cfg = smoke_config("qwen3_1p7b")
    B, S = 2, 16
    params = M.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    l1, _ = M.forward_train(cfg, params, tokens, labels, None)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = M.forward_train(cfg2, params, tokens, labels, None)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_decode_cost_dominated_by_kv_and_params():
    from repro.configs import get_config

    cfg = get_config("granite_3_8b")
    cost = cell_cost(cfg, SHAPES["decode_32k"])
    # decode arithmetic intensity must be tiny (memory-bound regime)
    intensity = cost.flops / cost.hbm_bytes
    assert intensity < 20.0  # flops per byte far below v5e's ~240 ridge

    # SWA caps the long-context decode cost for mixtral
    mix = get_config("mixtral_8x7b")
    c500 = cell_cost(mix, SHAPES["long_500k"])
    c32 = cell_cost(mix, SHAPES["decode_32k"])
    assert c500.hbm_bytes < c32.hbm_bytes  # batch 1 + windowed cache
