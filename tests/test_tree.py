"""Property tests for the BFS tree layout and reference search."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tree as T


@st.composite
def key_value_sets(draw, max_n=600):
    n = draw(st.integers(1, max_n))
    keys = draw(
        st.lists(
            st.integers(-(2**30), 2**30 - 1), min_size=n, max_size=n, unique=True
        )
    )
    values = draw(st.lists(st.integers(0, 2**30), min_size=n, max_size=n))
    return np.array(keys, np.int32), np.array(values, np.int32)


class TestLayout:
    def test_level_offsets(self):
        assert [T.level_offset(l) for l in range(5)] == [0, 1, 3, 7, 15]
        assert [T.level_size(l) for l in range(5)] == [1, 2, 4, 8, 16]

    def test_eytzinger_is_bst(self, small_tree):
        tree, _, _ = small_tree
        keys = np.asarray(tree.keys)
        n = tree.n_nodes
        for i in range((n - 1) // 2):
            l, r = 2 * i + 1, 2 * i + 2
            assert keys[l] < keys[i] or keys[l] == T.SENTINEL_KEY
            assert keys[r] > keys[i] or keys[r] == T.SENTINEL_KEY

    def test_inorder_is_sorted(self, small_tree):
        tree, keys, _ = small_tree
        bfs = np.asarray(tree.keys)

        def inorder(i, out):
            if i >= tree.n_nodes:
                return
            inorder(2 * i + 1, out)
            out.append(bfs[i])
            inorder(2 * i + 2, out)

        out = []
        import sys

        sys.setrecursionlimit(100000)
        inorder(0, out)
        real = [k for k in out if k != T.SENTINEL_KEY]
        assert real == sorted(keys.tolist())

    @given(key_value_sets())
    @settings(max_examples=25, deadline=None)
    def test_search_finds_all_inserted(self, kv):
        keys, values = kv
        tree = T.build_tree(keys, values)
        v, f = T.search_reference(tree, jnp.asarray(keys))
        assert bool(np.all(np.asarray(f)))
        assert np.array_equal(np.asarray(v), values)

    @given(key_value_sets(), st.lists(st.integers(-(2**31), 2**31 - 2), min_size=1, max_size=64))
    @settings(max_examples=25, deadline=None)
    def test_search_rejects_absent(self, kv, probes):
        keys, values = kv
        tree = T.build_tree(keys, values)
        probes = np.array(probes, np.int64)
        present = np.isin(probes, keys.astype(np.int64))
        v, f = T.search_reference(tree, jnp.asarray(probes.astype(np.int32)))
        assert np.array_equal(np.asarray(f), present)

    def test_subtree_extraction_consistent(self, small_tree):
        tree, keys, values = small_tree
        split = 3
        kv = dict(zip(keys.tolist(), values.tolist()))
        for s in range(1 << split):
            sub = tree.subtree(split, s)
            sk = np.asarray(sub.keys)
            real = sk[sk != T.SENTINEL_KEY]
            # every subtree key must be found in the subtree itself
            v, f = T.subtree_search(
                sub.keys, sub.values, sub.height, jnp.asarray(real),
                jnp.ones(real.shape, bool),
            )
            assert bool(np.all(np.asarray(f)))
            for k, vv in zip(real.tolist(), np.asarray(v).tolist()):
                assert kv[k] == vv

    def test_register_route_matches_subtrees(self, small_tree):
        tree, keys, _ = small_tree
        split = 3
        dest, val, found = T.register_layer_route(tree, jnp.asarray(keys), split)
        dest = np.asarray(dest)
        found = np.asarray(found)
        # routed keys must actually live in the subtree they were routed to
        for s in range(1 << split):
            sub = tree.subtree(split, s)
            sk = set(np.asarray(sub.keys).tolist()) - {int(T.SENTINEL_KEY)}
            routed = keys[(dest == s) & ~found]
            assert set(routed.tolist()) <= sk

    def test_build_rejects_duplicates(self):
        with pytest.raises(ValueError):
            T.build_tree(np.array([1, 1, 2]), np.array([0, 1, 2]))
