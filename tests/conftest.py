"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests and benches must
see the real single-CPU device; only launch/dryrun.py (a separate process)
forces 512 placeholder devices."""

import importlib.util
import os

import numpy as np
import pytest

try:  # real hypothesis when available; deterministic fallback otherwise
    import hypothesis  # noqa: F401

    _USING_SHIM = False
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()
    _USING_SHIM = True


def pytest_addoption(parser):
    # CI pins the differential harness with --hypothesis-seed; real
    # hypothesis registers that flag itself, so only the shim (which is
    # deterministic regardless -- the value is accepted and ignored) needs
    # to add it to keep the same command line working everywhere.
    if _USING_SHIM:
        parser.addoption(
            "--hypothesis-seed",
            action="store",
            default=None,
            help="accepted for CI parity; the deterministic fallback shim "
            "derives per-test seeds from test names instead",
        )

from repro.core import tree as tree_lib
from repro.data.keysets import make_tree_data


@pytest.fixture(scope="session")
def small_tree():
    keys, values = make_tree_data(1000, seed=7)
    return tree_lib.build_tree(keys, values), keys, values


@pytest.fixture(scope="session")
def medium_tree():
    keys, values = make_tree_data((1 << 12) - 1, seed=11)
    return tree_lib.build_tree(keys, values), keys, values
