"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests and benches must
see the real single-CPU device; only launch/dryrun.py (a separate process)
forces 512 placeholder devices."""

import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.data.keysets import make_tree_data


@pytest.fixture(scope="session")
def small_tree():
    keys, values = make_tree_data(1000, seed=7)
    return tree_lib.build_tree(keys, values), keys, values


@pytest.fixture(scope="session")
def medium_tree():
    keys, values = make_tree_data((1 << 12) - 1, seed=11)
    return tree_lib.build_tree(keys, values), keys, values
