"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests and benches must
see the real single-CPU device; the multi-device cases (``multi_device_host``
below, launch/dryrun.py) force their device counts in SEPARATE processes."""

import importlib.util
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:  # real hypothesis when available; deterministic fallback otherwise
    import hypothesis  # noqa: F401

    _USING_SHIM = False
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()
    _USING_SHIM = True


def pytest_addoption(parser):
    # CI pins the differential harness with --hypothesis-seed; real
    # hypothesis registers that flag itself, so only the shim (which is
    # deterministic regardless -- the value is accepted and ignored) needs
    # to add it to keep the same command line working everywhere.
    if _USING_SHIM:
        parser.addoption(
            "--hypothesis-seed",
            action="store",
            default=None,
            help="accepted for CI parity; the deterministic fallback shim "
            "derives per-test seeds from test names instead",
        )

from repro.core import tree as tree_lib
from repro.data.keysets import make_tree_data

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced_multi_device(body: str, devices: int = 8, timeout: int = 1800) -> str:
    """Run a test snippet on a forced ``devices``-CPU host.

    The XLA device-count flag must be set BEFORE jax initializes, and this
    process must keep its single real device, so the snippet executes in a
    subprocess with the repo's src on the path and the common imports
    (numpy/jax/make_mesh) pre-bound -- the shared implementation behind
    tests/test_distributed.py and the sharded differential suite.
    """
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(_ROOT, 'src')!r})
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.sharding.compat import make_mesh
        """
    ) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def multi_device_host():
    """Fixture handle on ``run_forced_multi_device`` (8 fake devices default)."""
    return run_forced_multi_device


@pytest.fixture(scope="session")
def small_tree():
    keys, values = make_tree_data(1000, seed=7)
    return tree_lib.build_tree(keys, values), keys, values


@pytest.fixture(scope="session")
def medium_tree():
    keys, values = make_tree_data((1 << 12) - 1, seed=11)
    return tree_lib.build_tree(keys, values), keys, values
