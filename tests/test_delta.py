"""Delta write buffer unit tests (DESIGN.md §7): ingest semantics, empty-
buffer identity, compaction triggers, and the device-residency guarantee."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import delta as D
from repro.core import tree as T
from repro.core.engine import BSTEngine, EngineConfig
from repro.data.keysets import make_tree_data


def _tree_and_kv(n=200, seed=0):
    keys, values = make_tree_data(n, seed=seed)
    return T.build_tree(keys, values), dict(zip(keys.tolist(), values.tolist()))


def _ingest(tree, delta, ks, vs, ds):
    ks = jnp.asarray(np.asarray(ks, np.int32))
    res = T.search_reference_ordered(tree, ks)
    return D.ingest(
        delta,
        ks,
        jnp.asarray(np.asarray(vs, np.int32)),
        jnp.asarray(np.asarray(ds, bool)),
        jnp.ones(ks.shape, bool),
        res.found,
        res.rank,
    )


def test_ingest_sorted_dedup_last_wins():
    tree, _ = _tree_and_kv()
    d = D.empty(8)
    # same key three times in one batch (upsert, delete, upsert): last wins
    d = _ingest(tree, d, [9, 9, 9, 5], [1, 0, 3, 50], [False, True, False, False])
    k = np.asarray(d.keys)
    assert int(d.count) == 2
    assert k[0] == 5 and k[1] == 9 and np.all(k[2:] == T.SENTINEL_KEY)
    assert np.asarray(d.values)[1] == 3 and not bool(np.asarray(d.tombstone)[1])
    # a later batch overrides the buffered entry (old-then-new stable order)
    d = _ingest(tree, d, [9], [0], [True])
    assert int(d.count) == 2 and bool(np.asarray(d.tombstone)[1])
    # weights: 5 and 9 are absent from the tree -> upsert-new +1, dead 0
    np.testing.assert_array_equal(np.asarray(D.weights(d))[:2], [1, 0])


def test_empty_buffer_is_bitwise_identity():
    """An attached-but-empty buffer must not change ANY answer -- the same
    compiled function serves the engine before its first write."""
    keys, values = make_tree_data(300, seed=4)
    rng = np.random.default_rng(0)
    q = rng.choice(np.concatenate([keys, keys + 1]), 64).astype(np.int32)
    lo = np.sort(q)
    hi = (lo + rng.integers(0, 30, lo.size)).astype(np.int32)
    for strategy, n in (("hrz", 1), ("dup", 4), ("hyb", 4)):
        plain = BSTEngine(keys, values, EngineConfig(strategy=strategy, n_trees=n))
        live = BSTEngine(
            keys, values,
            EngineConfig(strategy=strategy, n_trees=n, delta_capacity=16),
        )
        for op, a, b in (
            ("lookup", q, None),
            ("predecessor", q, None),
            ("successor", q, None),
            ("range_count", lo, hi),
            ("range_scan", lo, hi),
        ):
            r1 = plain.query(op, a, b) if b is not None else plain.query(op, a)
            r2 = live.query(op, a, b) if b is not None else live.query(op, a)
            r1 = r1 if isinstance(r1, tuple) else (r1,)
            r2 = r2 if isinstance(r2, tuple) else (r2,)
            for c1, c2 in zip(r1, r2):
                np.testing.assert_array_equal(
                    np.asarray(c1), np.asarray(c2), err_msg=f"{strategy}/{op}"
                )


def test_updates_never_leave_device():
    """The DESIGN.md §7 acceptance gate: the whole update path -- query
    with live buffer, batch ingest, compaction merge -- must trace under
    jax abstract evaluation.  Any host round-trip (np.asarray on a traced
    value, python branching on device data) raises a TracerError here."""
    keys, values = make_tree_data(200, seed=1)
    eng = BSTEngine(keys, values, EngineConfig(strategy="hrz", delta_capacity=16))
    q = jax.ShapeDtypeStruct((32,), jnp.int32)
    d = jax.eval_shape(lambda: eng.delta)  # DeltaBuffer of abstract leaves

    # 1) queries with the buffer attached (every op) trace end to end
    from repro.core import plans as plans_lib

    for op in ("lookup", "predecessor", "successor"):
        jax.eval_shape(
            lambda qq, dd, op=op: plans_lib.ordered_query(eng.plan, op, qq, delta=dd),
            q, d,
        )
    jax.eval_shape(
        lambda lo, hi, dd: plans_lib.ordered_query(
            eng.plan, "range_scan", lo, hi, k=4, delta=dd
        ),
        q, q, d,
    )

    # 2) the jitted ingest program traces (descend + classify + merge)
    m = 8
    jax.eval_shape(
        eng._ingest,
        d,
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.bool_),
        jax.ShapeDtypeStruct((m,), jnp.bool_),
    )

    # 3) the compaction merge traces (the single host sync -- the count
    # scalar -- happens OUTSIDE compact_sorted, after it returns)
    rank_to_bfs = jnp.asarray(T.rank_to_bfs_indices(eng.tree.height))
    out_k, out_v, count = jax.eval_shape(
        lambda tk, tv, dd: D.compact_sorted(
            tk, tv, rank_to_bfs, eng.tree.n_real, dd,
            eng.tree.n_real + eng.config.delta_capacity,
        ),
        eng.tree.keys, eng.tree.values, d,
    )
    assert count.shape == ()


def test_high_water_triggers_compaction():
    keys, values = make_tree_data(100, seed=2)
    cfg = EngineConfig(strategy="hrz", delta_capacity=8, delta_high_water=6)
    eng = BSTEngine(keys, values, cfg)
    eng.apply_updates(insert_keys=[1, 3, 5], insert_values=[1, 3, 5])
    assert eng.compactions == 0 and eng.pending_writes() == 3
    eng.apply_updates(insert_keys=[7, 9, 11], insert_values=[7, 9, 11])
    assert eng.compactions == 1 and eng.pending_writes() == 0
    v, f = eng.lookup(np.array([1, 3, 5, 7, 9, 11], np.int32))
    assert np.all(np.asarray(f)) and np.array_equal(
        np.asarray(v), [1, 3, 5, 7, 9, 11]
    )
    # a batch larger than the capacity splits and compacts as it goes
    big = np.arange(13, 63, 2, dtype=np.int32)
    eng.apply_updates(insert_keys=big, insert_values=big * 2)
    v, f = eng.lookup(big)
    assert np.all(np.asarray(f))
    np.testing.assert_array_equal(np.asarray(v), big * 2)


CAP = 8


@pytest.mark.parametrize("batch", [CAP, CAP + 1, 3 * CAP])
def test_apply_ops_batch_vs_capacity_edges(batch):
    """Oversized batches chunk through interleaved compactions; occupancy
    never exceeds the capacity between triggers, and no entry is silently
    dropped -- checked against a dict oracle.  Covers batch == cap (the
    exact-fit edge), cap + 1 (one lane past it) and 3 * cap (multiple
    interleaved compactions), each on a buffer pre-filled above zero."""
    keys, values = make_tree_data(120, seed=6)
    # high_water == capacity: compaction happens only when it MUST, so the
    # exact-fit edge genuinely fills the buffer before the next trigger.
    cfg = EngineConfig(strategy="hrz", delta_capacity=CAP, delta_high_water=CAP)
    eng = BSTEngine(keys, values, cfg)
    kv = dict(zip(keys.tolist(), values.tolist()))

    eng.apply_ops([1001, 1003, 1005], [1, 3, 5], [False] * 3)
    kv.update({1001: 1, 1003: 3, 1005: 5})
    assert eng.pending_writes() == 3

    rng = np.random.default_rng(batch)
    bk = rng.choice(np.arange(1000, 1000 + 2 * batch), batch, replace=False)
    bv = rng.integers(0, 10**6, batch).astype(np.int32)
    bd = rng.integers(0, 4, batch) == 0  # ~25% tombstones
    eng.apply_ops(bk.astype(np.int32), bv, bd)
    assert eng.pending_writes() <= CAP
    if batch > CAP:
        assert eng.compactions >= batch // CAP
    for k, v, d in zip(bk.tolist(), bv.tolist(), bd.tolist()):
        if d:
            kv.pop(k, None)
        else:
            kv[k] = v

    probes = np.concatenate([bk, [1001, 1003, 1005]]).astype(np.int32)
    got_v, got_f = eng.lookup(probes)
    for q, v, f in zip(probes.tolist(), np.asarray(got_v), np.asarray(got_f)):
        assert bool(f) == (q in kv), q
        if q in kv:
            assert int(v) == kv[q], q
    # and once more after absorbing everything into a fresh snapshot
    eng.compact()
    got_v, got_f = eng.lookup(probes)
    for q, v, f in zip(probes.tolist(), np.asarray(got_v), np.asarray(got_f)):
        assert bool(f) == (q in kv) and (q not in kv or int(v) == kv[q]), q


def test_delta_capacity_config_validation():
    """Capacity 0 -> clear 'write path disabled' error on apply_ops;
    negative capacity and an unreachable high-water mark fail at config
    construction (they could silently overflow the buffer otherwise)."""
    keys, values = make_tree_data(50, seed=3)
    eng = BSTEngine(keys, values, EngineConfig(strategy="hrz", delta_capacity=0))
    with pytest.raises(ValueError, match="delta_capacity == 0"):
        eng.apply_ops([1], [1], [False])
    with pytest.raises(ValueError, match="delta_capacity must be >= 0"):
        EngineConfig(strategy="hrz", delta_capacity=-4)
    with pytest.raises(ValueError, match="delta_high_water"):
        EngineConfig(strategy="hrz", delta_capacity=8, delta_high_water=9)
    with pytest.raises(ValueError, match="delta_high_water"):
        EngineConfig(strategy="hrz", delta_capacity=8, delta_high_water=0)
    with pytest.raises(ValueError, match="valid mask"):
        BSTEngine(
            keys, values, EngineConfig(strategy="hrz", delta_capacity=4)
        ).apply_ops([1, 2], [1, 2], [False, False], valid=[True])


def test_read_only_engine_rejects_apply_ops():
    keys, values = make_tree_data(50, seed=3)
    eng = BSTEngine(keys, values, EngineConfig(strategy="hrz"))
    with pytest.raises(ValueError, match="write path disabled"):
        eng.apply_ops([1], [1], [False])
    # but apply_updates falls back to bulk rebuild + fresh plan
    eng.apply_updates(insert_keys=[1], insert_values=[10])
    v, f = eng.lookup(np.array([1], np.int32))
    assert bool(f[0]) and int(v[0]) == 10


def test_compaction_preserves_oracle_state():
    tree, kv = _tree_and_kv(150, seed=5)
    d = D.empty(16)
    ks = [1, 3, int(np.asarray(tree.keys)[0]), 5, 3]
    vs = [10, 30, 999, 50, 31]
    ds = [False, False, False, False, True]  # 3 inserted then tombstoned
    d = _ingest(tree, d, ks, vs, ds)
    kv[1] = 10
    kv[int(np.asarray(tree.keys)[0])] = 999
    kv[5] = 50
    tree2 = D.compact(tree, d)
    sk = np.asarray(tree2.keys)[T.rank_to_bfs_indices(tree2.height)][: tree2.n_real]
    sv = np.asarray(tree2.values)[T.rank_to_bfs_indices(tree2.height)][: tree2.n_real]
    assert sk.tolist() == sorted(kv)
    assert sv.tolist() == [kv[k] for k in sorted(kv)]


def test_kernel_delta_matches_ref_property(medium_tree):
    """The in-pallas_call buffer resolution == the jnp twin, bit for bit."""
    tree, keys, _ = medium_tree
    rng = np.random.default_rng(9)
    d = D.empty(32)
    nk = rng.choice(np.concatenate([keys[:64], keys[:64] + 1]), 24, replace=False)
    nv = rng.integers(0, 10**6, 24).astype(np.int32)
    nd = rng.integers(0, 2, 24).astype(bool)
    d = _ingest(tree, d, nk.astype(np.int32), nv, nd)
    q = rng.choice(np.concatenate([keys, keys + 1]), 700).astype(np.int32)
    from repro.kernels import ops as kops

    args = (tree.keys[None, :], tree.values[None, :], jnp.asarray(q)[None, :])
    kw = dict(height=tree.height, delta=D.operands(d))
    ref_out = kops.bst_ordered_forest(*args, use_ref=True, **kw)
    ker_out = kops.bst_ordered_forest(*args, use_ref=False, **kw)
    for a, b in zip(ref_out, ker_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ref2 = kops.bst_search_forest(*args, use_ref=True, **kw)
    ker2 = kops.bst_search_forest(*args, use_ref=False, **kw)
    for a, b in zip(ref2, ker2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
