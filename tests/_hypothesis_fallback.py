"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 suite property-tests the BST layers with hypothesis, but the
container must not grow new dependencies.  This shim implements the tiny
subset the tests use -- ``given``, ``settings`` and the ``strategies``
combinators ``integers`` / ``lists`` / ``tuples`` / ``sampled_from`` /
``composite`` -- as a *deterministic* example generator: every strategy
draws from a ``numpy`` RNG seeded by the test name and example index, so a
failure reproduces bit-identically on every run and machine.

``install()`` registers the shim under ``sys.modules['hypothesis']`` (and
``hypothesis.strategies``); ``tests/conftest.py`` calls it only when the
real library is missing, so environments that have hypothesis keep its full
shrinking/coverage behaviour.
"""

from __future__ import annotations

import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10
_UNIQUE_ATTEMPTS = 50  # rejection-sampling budget per unique element


class Strategy:
    """A value generator: ``example(rng)`` draws one deterministic value."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng) -> object:
        return self._sample(rng)


def integers(min_value, max_value) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> Strategy:
    seq = list(elements)
    return Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def tuples(*strategies) -> Strategy:
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements, min_size=0, max_size=None, unique=False) -> Strategy:
    if max_size is None:
        max_size = min_size + 10

    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out, seen = [], set()
        for _ in range(n):
            for _attempt in range(_UNIQUE_ATTEMPTS):
                v = elements.example(rng)
                if v not in seen:
                    seen.add(v)
                    out.append(v)
                    break
        return out

    return Strategy(sample)


def composite(fn):
    """``@st.composite`` -- ``fn(draw, *args)`` becomes a strategy factory."""

    def builder(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)

        return Strategy(sample)

    return builder


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Records max_examples on the function (either side of ``@given``)."""

    def deco(fn):
        cfg = getattr(fn, "_shim_settings", None)
        if cfg is None:
            fn._shim_settings = {"max_examples": max_examples}
        else:
            cfg["max_examples"] = max_examples
        return fn

    return deco


def given(*strategies):
    """Run the test over deterministic examples of the given strategies."""

    def deco(fn):
        shim_settings = getattr(fn, "_shim_settings", {})
        seed_base = zlib.crc32(fn.__qualname__.encode())

        # NOTE: signature intentionally hides the strategy parameters so
        # pytest does not mistake them for fixtures (hypothesis does the
        # same); ``*args`` still forwards ``self`` for test methods.
        def wrapper(*args, **kwargs):
            n = wrapper._shim_settings.get("max_examples", DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = np.random.default_rng((seed_base + i) % 2**32)
                drawn = [s.example(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper._shim_settings = dict(shim_settings)
        return wrapper

    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.lists = lists
    st_mod.tuples = tuples
    st_mod.sampled_from = sampled_from
    st_mod.composite = composite

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__is_deterministic_fallback__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
