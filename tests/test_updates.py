"""Bulk insert/delete (the paper's announced extension, DESIGN.md §2)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import tree as T
from repro.core.engine import BSTEngine, PAPER_CONFIGS, EngineConfig
from repro.core.updates import bulk_delete, bulk_insert, sorted_view
from repro.data.keysets import make_tree_data


def _probe(tree, kv):
    keys = np.array(sorted(kv), np.int32)
    v, f = T.search_reference(tree, jnp.asarray(keys))
    assert bool(np.all(np.asarray(f)))
    for k, vv in zip(keys.tolist(), np.asarray(v).tolist()):
        assert kv[k] == vv


def test_bulk_insert_upsert_and_layout():
    keys, values = make_tree_data(500, seed=0)
    tree = T.build_tree(keys, values)
    kv = dict(zip(keys.tolist(), values.tolist()))
    # new keys (odd: absent) + overwrites of existing ones
    nk = np.array([3, 5, 7, int(keys[0]), int(keys[10])], np.int32)
    nv = np.array([30, 50, 70, 999, 888], np.int32)
    tree2 = bulk_insert(tree, nk, nv)
    kv.update(dict(zip(nk.tolist(), nv.tolist())))
    _probe(tree2, kv)
    # layout invariant: in-order == sorted
    sk, _ = sorted_view(tree2)
    assert np.all(np.diff(sk) > 0)


def test_bulk_delete_then_search():
    keys, values = make_tree_data(300, seed=1)
    tree = T.build_tree(keys, values)
    kv = dict(zip(keys.tolist(), values.tolist()))
    drop = keys[::7]
    tree2 = bulk_delete(tree, drop)
    for k in drop:
        kv.pop(int(k))
    _probe(tree2, kv)
    v, f = T.search_reference(tree2, jnp.asarray(drop.astype(np.int32)))
    assert not np.any(np.asarray(f))
    # scalar delete keeps working (np.unique used to coerce 0-d input)
    tree3 = bulk_delete(tree2, int(keys[0]))
    _, f = T.search_reference(tree3, jnp.asarray(keys[:1].astype(np.int32)))
    assert not bool(np.asarray(f)[0])


@given(
    st.integers(5, 300),
    st.lists(st.tuples(st.integers(0, 10**6), st.integers(0, 10**6)),
             min_size=1, max_size=80),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_update_stream_property(n0, updates, seed):
    """Random insert/delete stream == python-dict oracle."""
    keys, values = make_tree_data(n0, seed=seed % 997)
    tree = T.build_tree(keys, values)
    oracle = dict(zip(keys.tolist(), values.tolist()))
    ins = np.array([(k * 2 + 1) % (2**30) for k, _ in updates], np.int32)
    vals = np.array([v % (2**30) for _, v in updates], np.int32)
    tree = bulk_insert(tree, ins, vals)
    for k, v in zip(ins.tolist(), vals.tolist()):
        oracle[k] = v  # upsert; duplicate batch keys resolved last-wins by
    # numpy stable unique in bulk_insert keeps LAST occurrence
    dup = {}
    for k, v in zip(ins.tolist(), vals.tolist()):
        dup[k] = v
    oracle.update(dup)
    _probe(tree, oracle)
    # delete half of the inserted keys
    drop = ins[::2]
    tree = bulk_delete(tree, drop)
    for k in np.unique(drop).tolist():
        oracle.pop(k, None)
    if oracle:
        _probe(tree, oracle)


def test_engine_serves_updated_tree():
    """Snapshot-swap serving: engines rebuild from an updated tree."""
    keys, values = make_tree_data(1000, seed=2)
    eng = BSTEngine(keys, values, PAPER_CONFIGS["Hyb8q"])
    tree2 = bulk_insert(eng.tree, np.array([1], np.int32), np.array([42], np.int32))
    sk, sv = sorted_view(tree2)
    eng2 = BSTEngine(sk, sv, PAPER_CONFIGS["Hyb8q"])
    v, f = eng2.lookup(np.array([1], np.int32))
    assert bool(f[0]) and int(v[0]) == 42


# ---------------------------------------------------- compaction invariants
def assert_layout_invariants(tree):
    """Every layout contract the ordered ops depend on (DESIGN.md §6/§7).

    The jnp compaction path rebuilds these BY CONSTRUCTION; this pins them
    explicitly so a future merge/re-layout bug cannot slip through a test
    that only samples queries:

      * perfect-tree shape, minimal height for ``n_real``;
      * the in-order view (gather through rank_to_bfs) is strictly sorted
        with all sentinels packed at the top ranks -- the substrate of the
        rank arithmetic;
      * the BFS image is exactly the Eytzinger gather of that sorted view,
        i.e. rank -> BFS and BFS -> rank are inverse bijections;
      * the BST ordering of the BFS layout itself (every descent's
        compare-branch correctness).
    """
    keys = np.asarray(tree.keys)
    n = keys.size
    assert n == (1 << (tree.height + 1)) - 1, "not a perfect tree"
    assert tree.height == T.height_for(tree.n_real), "height not minimal"
    assert int((keys != T.SENTINEL_KEY).sum()) == tree.n_real

    r2b = T.rank_to_bfs_indices(tree.height)
    b2r = T.bfs_inorder_ranks(tree.height)
    view = keys[r2b]
    assert np.all(np.diff(view[: tree.n_real].astype(np.int64)) > 0), (
        "in-order view not strictly sorted"
    )
    assert np.all(view[tree.n_real :] == T.SENTINEL_KEY), (
        "sentinels not packed at the top ranks"
    )
    # rank<->BFS bijection + Eytzinger layout == gather of the sorted view
    assert np.array_equal(r2b[b2r], np.arange(n))
    assert np.array_equal(keys, view[b2r])
    # BST property in BFS indexing (int64 to keep sentinel compares exact)
    k64 = keys.astype(np.int64)
    parents = (np.arange(1, n) - 1) // 2
    left = np.arange(1, n, 2)
    right = np.arange(2, n, 2)
    assert np.all(k64[left] <= k64[parents[left - 1]])
    assert np.all(k64[right] >= k64[parents[right - 1]])


def test_bulk_ops_reestablish_layout_invariants():
    keys, values = make_tree_data(700, seed=6)
    tree = T.build_tree(keys, values)
    assert_layout_invariants(tree)
    tree = bulk_insert(tree, np.arange(1, 101, 2, np.int32), np.arange(50, dtype=np.int32))
    assert_layout_invariants(tree)
    tree = bulk_delete(tree, keys[::3])
    assert_layout_invariants(tree)


def test_jnp_compaction_invariants_after_every_merge():
    """A random insert/delete stream through the delta engine: after EVERY
    compaction the new snapshot must satisfy all layout invariants."""
    keys, values = make_tree_data(300, seed=8)
    cfg = EngineConfig(strategy="hrz", delta_capacity=16, delta_high_water=12)
    eng = BSTEngine(keys, values, cfg)
    oracle = dict(zip(keys.tolist(), values.tolist()))
    rng = np.random.default_rng(13)
    compactions_seen = 0
    for step in range(8):
        nk = rng.integers(1, 900, 10).astype(np.int32)
        nv = rng.integers(0, 10**6, 10).astype(np.int32)
        dk = rng.choice(np.array(sorted(oracle), np.int32), 3)
        eng.apply_updates(insert_keys=nk, insert_values=nv, delete_keys=dk)
        for k in np.unique(dk).tolist():
            oracle.pop(k, None)
        last = {}
        for k, v in zip(nk.tolist(), nv.tolist()):
            last[k] = v
        oracle.update(last)
        if eng.compactions != compactions_seen:
            compactions_seen = eng.compactions
            assert_layout_invariants(eng.tree)
            sk, sv = sorted_view(eng.tree)
            assert sk.tolist() == sorted(oracle)
            assert sv.tolist() == [oracle[k] for k in sorted(oracle)]
    assert compactions_seen >= 2, "stream never exercised compaction"
    eng.compact()
    assert_layout_invariants(eng.tree)
    sk, sv = sorted_view(eng.tree)
    assert sk.tolist() == sorted(oracle)
