"""Bulk insert/delete (the paper's announced extension, DESIGN.md §2)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import tree as T
from repro.core.engine import BSTEngine, PAPER_CONFIGS, EngineConfig
from repro.core.updates import bulk_delete, bulk_insert, sorted_view
from repro.data.keysets import make_tree_data


def _probe(tree, kv):
    keys = np.array(sorted(kv), np.int32)
    v, f = T.search_reference(tree, jnp.asarray(keys))
    assert bool(np.all(np.asarray(f)))
    for k, vv in zip(keys.tolist(), np.asarray(v).tolist()):
        assert kv[k] == vv


def test_bulk_insert_upsert_and_layout():
    keys, values = make_tree_data(500, seed=0)
    tree = T.build_tree(keys, values)
    kv = dict(zip(keys.tolist(), values.tolist()))
    # new keys (odd: absent) + overwrites of existing ones
    nk = np.array([3, 5, 7, int(keys[0]), int(keys[10])], np.int32)
    nv = np.array([30, 50, 70, 999, 888], np.int32)
    tree2 = bulk_insert(tree, nk, nv)
    kv.update(dict(zip(nk.tolist(), nv.tolist())))
    _probe(tree2, kv)
    # layout invariant: in-order == sorted
    sk, _ = sorted_view(tree2)
    assert np.all(np.diff(sk) > 0)


def test_bulk_delete_then_search():
    keys, values = make_tree_data(300, seed=1)
    tree = T.build_tree(keys, values)
    kv = dict(zip(keys.tolist(), values.tolist()))
    drop = keys[::7]
    tree2 = bulk_delete(tree, drop)
    for k in drop:
        kv.pop(int(k))
    _probe(tree2, kv)
    v, f = T.search_reference(tree2, jnp.asarray(drop.astype(np.int32)))
    assert not np.any(np.asarray(f))


@given(
    st.integers(5, 300),
    st.lists(st.tuples(st.integers(0, 10**6), st.integers(0, 10**6)),
             min_size=1, max_size=80),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_update_stream_property(n0, updates, seed):
    """Random insert/delete stream == python-dict oracle."""
    keys, values = make_tree_data(n0, seed=seed % 997)
    tree = T.build_tree(keys, values)
    oracle = dict(zip(keys.tolist(), values.tolist()))
    ins = np.array([(k * 2 + 1) % (2**30) for k, _ in updates], np.int32)
    vals = np.array([v % (2**30) for _, v in updates], np.int32)
    tree = bulk_insert(tree, ins, vals)
    for k, v in zip(ins.tolist(), vals.tolist()):
        oracle[k] = v  # upsert; duplicate batch keys resolved last-wins by
    # numpy stable unique in bulk_insert keeps LAST occurrence
    dup = {}
    for k, v in zip(ins.tolist(), vals.tolist()):
        dup[k] = v
    oracle.update(dup)
    _probe(tree, oracle)
    # delete half of the inserted keys
    drop = ins[::2]
    tree = bulk_delete(tree, drop)
    for k in np.unique(drop).tolist():
        oracle.pop(k, None)
    if oracle:
        _probe(tree, oracle)


def test_engine_serves_updated_tree():
    """Snapshot-swap serving: engines rebuild from an updated tree."""
    keys, values = make_tree_data(1000, seed=2)
    eng = BSTEngine(keys, values, PAPER_CONFIGS["Hyb8q"])
    tree2 = bulk_insert(eng.tree, np.array([1], np.int32), np.array([42], np.int32))
    sk, sv = sorted_view(tree2)
    eng2 = BSTEngine(sk, sv, PAPER_CONFIGS["Hyb8q"])
    v, f = eng2.lookup(np.array([1], np.int32))
    assert bool(f[0]) and int(v[0]) == 42
