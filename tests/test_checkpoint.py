"""Checkpointing: atomicity, roundtrip, retention, async, elastic reshard."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.store import latest_step
from repro.configs import smoke_config
from repro.training.train_loop import TrainConfig, init_train_state


@pytest.fixture()
def state():
    cfg = smoke_config("tinyllama_1p1b")
    return init_train_state(cfg, TrainConfig(), jax.random.key(0))


def test_save_restore_roundtrip(tmp_path, state):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, state, extra={"pipeline_step": 7})
    restored, step, extra = restore_checkpoint(d, state)
    assert step == 7 and extra["pipeline_step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype  # bf16 survives the npz roundtrip


def test_latest_step_and_retention(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 5, 9):
        mgr.save(s, {"x": jnp.asarray([s])})
    assert latest_step(str(tmp_path)) == 9
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000005", "step_00000009"]


def test_async_save(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), save_async=True)
    mgr.save(3, state)
    mgr.wait()
    restored, step, _ = mgr.restore(state)
    assert step == 3


def test_interrupted_save_never_corrupts(tmp_path, state):
    """A stale temp dir must not shadow or break the good checkpoint."""
    d = str(tmp_path)
    save_checkpoint(d, 1, {"x": jnp.asarray([1.0])})
    os.makedirs(os.path.join(d, ".tmp_save_dead"), exist_ok=True)  # crashed writer
    restored, step, _ = restore_checkpoint(d, {"x": jnp.asarray([0.0])})
    assert step == 1 and float(restored["x"][0]) == 1.0


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"b": jnp.ones(3)})


def test_elastic_restore_resumes_training(tmp_path):
    """Save mid-training, restore, continue: loss keeps improving and the
    restored run matches a continuous run exactly (pure-function step)."""
    from repro.data.pipeline import TokenPipeline
    from repro.training.train_loop import make_train_step

    cfg = smoke_config("tinyllama_1p1b")
    tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    pipe = TokenPipeline(cfg.vocab_size, 16, 4, seed=5)
    step = make_train_step(cfg, tcfg, donate=False)

    state = init_train_state(cfg, tcfg, jax.random.key(0))
    for s in range(6):
        if s == 3:
            save_checkpoint(str(tmp_path), s, state)
        tokens, labels = pipe.batch_at(s)
        state, _ = step(state, jnp.asarray(tokens), jnp.asarray(labels))
    # "failure": restart from step 3 and replay
    restored, ck_step, _ = restore_checkpoint(
        str(tmp_path), init_train_state(cfg, tcfg, jax.random.key(0))
    )
    state2 = restored
    for s in range(ck_step, 6):
        tokens, labels = pipe.batch_at(s)
        state2, _ = step(state2, jnp.asarray(tokens), jnp.asarray(labels))
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )
