"""Ordered-query acceptance tests (DESIGN.md §6).

Every op (predecessor / successor / range_count / range_scan) must be
bit-identical to a plain NumPy ``searchsorted`` oracle across every
strategy, on BOTH the kernel and reference paths -- the same invariant the
membership search established -- including the edge cases: key below min /
above max, empty / whole-tree ranges, single-node trees, and
post-bulk-update snapshots.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import tree as T
from repro.core import updates as updates_lib
from repro.core.engine import BSTEngine, EngineConfig
from repro.data.keysets import make_tree_data
from repro.serving import BSTServer


# ------------------------------------------------------------ NumPy oracle
# The product's own sorted-view recovery is the oracle substrate: if its
# sentinel/upsert semantics change, these tests must see it.
sorted_view = updates_lib.sorted_view


def oracle(sk, sv, op, a, b=None, k=8):
    """Ground truth from np.searchsorted over the sorted key/value view."""
    a = np.asarray(a)
    if op == "lookup":
        i = np.searchsorted(sk, a, "left")
        found = (i < sk.size) & (sk[np.clip(i, 0, sk.size - 1)] == a)
        vals = np.where(found, sv[np.clip(i, 0, sk.size - 1)], T.SENTINEL_VALUE)
        return vals.astype(np.int32), found
    if op == "predecessor":  # floor: largest key <= a
        i = np.searchsorted(sk, a, "right") - 1
        ok = i >= 0
        ii = np.clip(i, 0, None)
        keys = np.where(ok, sk[ii], T.NO_PRED_KEY)
        vals = np.where(ok, sv[ii], T.SENTINEL_VALUE)
        return keys.astype(np.int32), vals.astype(np.int32), ok
    if op == "successor":  # ceiling: smallest key >= a
        i = np.searchsorted(sk, a, "left")
        ok = i < sk.size
        ii = np.clip(i, 0, sk.size - 1)
        keys = np.where(ok, sk[ii], T.NO_SUCC_KEY)
        vals = np.where(ok, sv[ii], T.SENTINEL_VALUE)
        return keys.astype(np.int32), vals.astype(np.int32), ok
    b = np.asarray(b)
    counts = (
        np.searchsorted(sk, b, "right") - np.searchsorted(sk, a, "left")
    ).clip(0)
    if op == "range_count":
        return counts.astype(np.int32)
    start = np.searchsorted(sk, a, "left")
    take = np.minimum(counts, k)
    keys = np.full((a.size, k), T.SENTINEL_KEY, np.int32)
    vals = np.full((a.size, k), T.SENTINEL_VALUE, np.int32)
    for i in range(a.size):
        t = take[i]
        keys[i, :t] = sk[start[i] : start[i] + t]
        vals[i, :t] = sv[start[i] : start[i] + t]
    return keys, vals, take.astype(np.int32)


def assert_op_matches(eng, sk, sv, op, a, b=None, k=8, msg=""):
    got = eng.query(op, a, b, k=k) if b is not None else eng.query(op, a)
    want = oracle(sk, sv, op, a, b, k=k)
    if not isinstance(got, tuple):
        got, want = (got,), (want,)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=f"{op} {msg}")


# The acceptance matrix: hrz, dup and hyb (queue AND direct), kernel and
# reference paths.  Kept to four configs so interpret-mode compiles stay
# tractable on CPU.
MATRIX = [
    EngineConfig(strategy="hrz"),
    EngineConfig(strategy="dup", n_trees=4),
    EngineConfig(strategy="hyb", n_trees=8, mapping="queue"),
    EngineConfig(strategy="hyb", n_trees=4, mapping="direct"),
]


def _mixed_queries(keys, rng, size=256):
    pool = np.concatenate([keys, keys + 1, keys - 1])
    return rng.choice(pool, size=size).astype(np.int32)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("cfg", MATRIX, ids=lambda c: c.name)
def test_all_ops_match_numpy_oracle(cfg, use_kernel):
    keys, values = make_tree_data(2047, seed=11)
    eng = BSTEngine(keys, values, dataclasses.replace(cfg, use_kernel=use_kernel))
    sk, sv = sorted_view(eng.tree)
    rng = np.random.default_rng(5)
    q = _mixed_queries(keys, rng)
    lo = rng.choice(np.concatenate([keys, keys + 1]), 256).astype(np.int32)
    hi = (lo + rng.integers(-8, 300, size=256)).astype(np.int32)
    tag = f"{cfg.name} kernel={use_kernel}"
    for op in ("lookup", "predecessor", "successor"):
        assert_op_matches(eng, sk, sv, op, q, msg=tag)
    for op in ("range_count", "range_scan"):
        assert_op_matches(eng, sk, sv, op, lo, hi, k=5, msg=tag)


@pytest.mark.parametrize("cfg", MATRIX, ids=lambda c: c.name)
def test_boundary_and_range_edges(cfg):
    """Below-min / above-max keys, empty / gap / whole-tree ranges."""
    keys, values = make_tree_data(500, seed=2)  # even keys 2..1000
    eng = BSTEngine(keys, values, cfg)
    sk, sv = sorted_view(eng.tree)
    kmin, kmax = int(sk[0]), int(sk[-1])

    # below min: no predecessor; above max: no successor
    q = np.array([kmin - 10, kmin - 1, kmax + 1, kmax + 10], np.int32)
    pk, pv, pok = eng.query("predecessor", q)
    assert not pok[0] and not pok[1] and pk[0] == T.NO_PRED_KEY
    assert pv[0] == T.SENTINEL_VALUE
    assert pok[2] and pok[3] and pk[2] == kmax  # floor above max == max
    skk, svv, sok = eng.query("successor", q)
    assert sok[0] and skk[0] == kmin
    assert not sok[2] and not sok[3] and skk[2] == T.NO_SUCC_KEY
    assert_op_matches(eng, sk, sv, "predecessor", q)
    assert_op_matches(eng, sk, sv, "successor", q)

    lo = np.array([50, 51, kmin, kmax + 1, kmin - 5], np.int32)
    hi = np.array([40, 51, kmax, kmax + 9, kmax + 5], np.int32)
    counts = np.asarray(eng.query("range_count", lo, hi))
    assert counts[0] == 0  # lo > hi: empty by clamping
    assert counts[1] == 0  # odd singleton: gap range, no keys
    assert counts[2] == sk.size  # whole tree
    assert counts[3] == 0  # beyond max
    assert counts[4] == sk.size  # superset of the key space
    assert_op_matches(eng, sk, sv, "range_count", lo, hi)
    assert_op_matches(eng, sk, sv, "range_scan", lo, hi, k=7)


@pytest.mark.parametrize("strategy,n_trees", [("hrz", 1), ("dup", 4)])
def test_single_node_tree(strategy, n_trees):
    """height 0: the one stored key is its own floor/ceiling; hyb needs
    height >= split and is covered at minimal height below."""
    eng = BSTEngine(
        np.array([100], np.int32),
        np.array([7], np.int32),
        EngineConfig(strategy=strategy, n_trees=n_trees),
    )
    sk, sv = sorted_view(eng.tree)
    q = np.array([99, 100, 101], np.int32)
    for op in ("lookup", "predecessor", "successor"):
        assert_op_matches(eng, sk, sv, op, q)
    lo = np.array([99, 100, 101], np.int32)
    hi = np.array([101, 100, 99], np.int32)
    counts = np.asarray(eng.query("range_count", lo, hi))
    assert counts.tolist() == [1, 1, 0]
    assert_op_matches(eng, sk, sv, "range_scan", lo, hi, k=2)


def test_minimal_hyb_tree():
    """The smallest tree a Hyb4 split fits (height 2): all ops, both paths."""
    keys = np.arange(2, 16, 2, dtype=np.int32)  # 7 keys -> height 2
    eng_cfg = EngineConfig(strategy="hyb", n_trees=4)
    for use_kernel in (False, True):
        eng = BSTEngine(
            keys, keys * 3, dataclasses.replace(eng_cfg, use_kernel=use_kernel)
        )
        sk, sv = sorted_view(eng.tree)
        q = np.arange(0, 18, dtype=np.int32)
        for op in ("lookup", "predecessor", "successor"):
            assert_op_matches(eng, sk, sv, op, q, msg=f"kernel={use_kernel}")
        assert_op_matches(
            eng, sk, sv, "range_count", q, q + 4, msg=f"kernel={use_kernel}"
        )


@pytest.mark.parametrize("cfg", MATRIX, ids=lambda c: c.name)
def test_ordered_after_bulk_updates(cfg):
    """Ranks, floors and scans re-align after bulk_insert + bulk_delete."""
    keys, values = make_tree_data(400, seed=8)
    tree = T.build_tree(keys, values)
    tree = updates_lib.bulk_delete(tree, keys[100:200])
    ins_k = np.arange(1, 101, 2, dtype=np.int32)  # odd keys: all new
    tree = updates_lib.bulk_insert(tree, ins_k, ins_k * 5)
    eng = BSTEngine.from_tree(tree, cfg)
    sk, sv = sorted_view(tree)
    rng = np.random.default_rng(9)
    q = rng.choice(
        np.concatenate([keys, ins_k, keys[100:200]]), 300
    ).astype(np.int32)
    for op in ("lookup", "predecessor", "successor"):
        assert_op_matches(eng, sk, sv, op, q, msg=cfg.name)
    hi = (q + rng.integers(0, 120, size=300)).astype(np.int32)
    assert_op_matches(eng, sk, sv, "range_count", q, hi, msg=cfg.name)
    assert_op_matches(eng, sk, sv, "range_scan", q, hi, k=6, msg=cfg.name)


# ------------------------------------------------------------------ serving
def test_server_typed_requests_and_per_op_accounting():
    keys, values = make_tree_data(1000, seed=7)
    srv = BSTServer(
        keys, values, EngineConfig(strategy="hyb", n_trees=4), chunk_size=256,
        scan_k=4,
    )
    sk, sv = sorted_view(srv.snapshot)
    rng = np.random.default_rng(0)
    q = rng.choice(np.concatenate([keys, keys + 1]), 517).astype(np.int32)
    lo = rng.choice(keys, 300).astype(np.int32)
    hi = (lo + rng.integers(0, 50, 300)).astype(np.int32)

    t_look = srv.submit(q)
    t_pred = srv.submit(q, op="predecessor")
    t_cnt = srv.submit_range(lo, hi, op="range_count")
    t_scan = srv.submit_range(lo, hi, op="range_scan")
    t_succ = srv.submit(np.array([1], np.int32), op="successor")
    assert srv.pending() == 517 * 2 + 300 * 2 + 1
    res = srv.drain()
    assert srv.pending() == 0

    np.testing.assert_array_equal(res[t_look][0], oracle(sk, sv, "lookup", q)[0])
    for got, want in zip(res[t_pred], oracle(sk, sv, "predecessor", q)):
        np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(res[t_cnt][0], oracle(sk, sv, "range_count", lo, hi))
    for got, want in zip(res[t_scan], oracle(sk, sv, "range_scan", lo, hi, k=4)):
        np.testing.assert_array_equal(got, want)
    skk, svv, sok = res[t_succ]
    assert bool(sok[0]) and int(skk[0]) == int(sk[0])

    s = srv.stats
    assert s.requests == 5 and s.served == s.submitted == srv.stats.served
    assert set(s.per_op) == {
        "lookup", "predecessor", "successor", "range_count", "range_scan"
    }
    assert s.per_op["lookup"].served == 517
    assert s.per_op["lookup"].chunks == -(-517 // 256)
    assert s.per_op["range_scan"].served == 300
    assert s.per_op["successor"].chunks == 1
    assert s.chunks == sum(o.chunks for o in s.per_op.values())
    assert s.found == int(oracle(sk, sv, "lookup", q)[1].sum())  # lookup hits only


def test_server_ordered_sees_fresh_snapshot_after_swap():
    keys, values = make_tree_data(300, seed=9)
    srv = BSTServer(keys, values, chunk_size=64)
    srv.apply_updates(
        insert_keys=np.array([1], np.int32), insert_values=np.array([42], np.int32)
    )
    pk, pv, ok = srv.predecessor(np.array([1], np.int32))
    assert bool(ok[0]) and int(pv[0]) == 42
    assert int(srv.range_count(1, 1)[0]) == 1
    K, V, taken = srv.range_scan(1, int(np.max(keys)))
    assert int(taken[0]) == srv.scan_k  # bounded scan clips to k
    assert int(K[0, 0]) == 1 and int(V[0, 0]) == 42
