"""Mamba2 SSD: chunked-parallel vs recurrent equivalence (the SSD duality)."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import ssm


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("mamba2_1p3b")


@pytest.fixture(scope="module")
def setup(cfg):
    shapes = ssm.ssm_params_shape(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(jax.random.key(0), len(leaves))
    params = jax.tree.unflatten(
        treedef, [jax.random.normal(k, s) * 0.1 for k, s in zip(ks, leaves)]
    )
    # stable dynamics: A_log ~ 0 -> A ~ -1
    params["A_log"] = jnp.zeros_like(params["A_log"])
    x = jax.random.normal(jax.random.key(1), (2, 24, cfg.d_model)) * 0.5
    return params, x


def _recurrent_oracle(cfg, params, x):
    """Token-by-token recurrence (ground truth for the parallel form)."""
    B, S, D = x.shape
    cache = ssm.init_ssm_cache(cfg, B)
    ys = []
    for t in range(S):
        y, cache = ssm.ssd_decode(cfg, params, x[:, t : t + 1, :], cache)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), cache


@pytest.mark.parametrize("chunk", [4, 8, 24])
def test_parallel_matches_recurrent(cfg, setup, chunk):
    params, x = setup
    c = dataclasses.replace(cfg, ssm_chunk=chunk)
    y_par, _ = ssm.ssd_parallel(c, params, x)
    y_rec, _ = _recurrent_oracle(c, params, x)
    np.testing.assert_allclose(
        np.asarray(y_par, np.float32), np.asarray(y_rec, np.float32),
        atol=3e-5, rtol=3e-4,
    )


def test_prefill_state_matches_recurrent(cfg, setup):
    params, x = setup
    y_pre, cache_pre = ssm.ssd_prefill(cfg, params, x)
    y_rec, cache_rec = _recurrent_oracle(cfg, params, x)
    np.testing.assert_allclose(
        np.asarray(cache_pre.state), np.asarray(cache_rec.state), atol=3e-5, rtol=3e-4
    )
    np.testing.assert_allclose(
        np.asarray(cache_pre.conv, np.float32),
        np.asarray(cache_rec.conv, np.float32),
        atol=1e-5,
    )


def test_prefill_then_decode_continues_exactly(cfg, setup):
    params, x = setup
    B, S, D = x.shape
    x2 = jax.random.normal(jax.random.key(9), (B, 4, D)) * 0.5
    full = jnp.concatenate([x, x2], axis=1)
    y_full, _ = ssm.ssd_parallel(cfg, params, full)
    _, cache = ssm.ssd_prefill(cfg, params, x)
    outs = []
    for t in range(4):
        y, cache = ssm.ssd_decode(cfg, params, x2[:, t : t + 1, :], cache)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec, np.float32),
        np.asarray(y_full[:, S:], np.float32),
        atol=3e-5, rtol=3e-4,
    )


def test_state_decays_not_explodes(cfg, setup):
    params, x = setup
    long_x = jnp.tile(x, (1, 8, 1))
    _, h = ssm.ssd_parallel(cfg, params, long_x)
    assert np.all(np.isfinite(np.asarray(h)))
    assert float(jnp.max(jnp.abs(h))) < 1e4
