"""MoE dispatch: the paper's technique inside the Mixtral FFN."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import moe
from repro.models import model as M


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("mixtral_8x7b")


@pytest.fixture(scope="module")
def setup(cfg):
    shapes = moe.moe_params_shape(cfg)
    key = jax.random.key(0)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(key, len(leaves))
    params = jax.tree.unflatten(
        treedef, [jax.random.normal(k, s) * 0.05 for k, s in zip(ks, leaves)]
    )
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.3
    return params, x


def _dense_oracle(cfg, params, x):
    """Every token through its top-k experts with NO capacity limit."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ params["router"]).astype(jnp.float32)
    gates, experts = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    out = jnp.zeros((T, D), jnp.float32)
    for e in range(cfg.n_experts):
        g = jax.nn.silu((xt @ params["w_gate"][e]).astype(jnp.float32))
        u = (xt @ params["w_up"][e]).astype(jnp.float32)
        y = (g * u) @ params["w_down"][e].astype(jnp.float32)
        for k in range(cfg.top_k):
            w = jnp.where(experts[:, k] == e, gates[:, k], 0.0)
            out = out + y * w[:, None]
    return out.reshape(B, S, D)


def test_moe_matches_dense_oracle_with_ample_capacity(cfg, setup):
    params, x = setup
    cfg_ample = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    for mapping in ("queue", "direct"):
        c = dataclasses.replace(cfg_ample, moe_dispatch=mapping)
        out, dropped = moe.moe_ffn(c, params, x)
        assert float(dropped) == 0.0
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(_dense_oracle(cfg, params, x)),
            atol=1e-4, rtol=1e-4,
        )


def test_queue_drops_at_most_direct(cfg, setup):
    """Paper Fig.5/6: direct mapping wastes slots the queue mapping fills."""
    params, x = setup
    for cf in (0.5, 0.75, 1.0, 1.5):
        dq = moe.moe_ffn(
            dataclasses.replace(cfg, capacity_factor=cf, moe_dispatch="queue"),
            params, x,
        )[1]
        dd = moe.moe_ffn(
            dataclasses.replace(cfg, capacity_factor=cf, moe_dispatch="direct"),
            params, x,
        )[1]
        assert float(dq) <= float(dd) + 1e-6, (cf, float(dq), float(dd))


def test_dropped_fraction_bounded_by_capacity(cfg, setup):
    params, x = setup
    c = dataclasses.replace(cfg, capacity_factor=0.25, moe_dispatch="queue")
    out, dropped = moe.moe_ffn(c, params, x)
    T = x.shape[0] * x.shape[1]
    cap = moe.expert_capacity(c, T)
    # kept items can never exceed n_experts * capacity
    assert float(dropped) >= 1.0 - (c.n_experts * cap) / (T * c.top_k) - 1e-6
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_moe_gradients_flow(cfg, setup):
    params, x = setup

    def loss(p):
        out, _ = moe.moe_ffn(cfg, p, x)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router must receive gradient (through the gate weights)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
