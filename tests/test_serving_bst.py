"""BSTServer: chunk accumulation, accounting, snapshot-swap serving."""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import tree as T
from repro.core.engine import PAPER_CONFIGS, EngineConfig
from repro.data.keysets import make_tree_data
from repro.serving import BSTServer


def _reference(tree, queries):
    v, f = T.search_reference(tree, jnp.asarray(np.asarray(queries, np.int32)))
    return np.asarray(v), np.asarray(f)


def test_chunk_accumulation_and_accounting():
    keys, values = make_tree_data(1000, seed=7)
    srv = BSTServer(keys, values, EngineConfig(strategy="hrz"), chunk_size=256)
    rng = np.random.default_rng(0)
    reqs = [
        rng.choice(np.concatenate([keys, keys + 1]), size=n).astype(np.int32)
        for n in (3, 256, 100, 517)  # odd sizes straddle chunk boundaries
    ]
    tickets = [srv.submit(r) for r in reqs]
    assert srv.pending() == sum(r.size for r in reqs)
    results = srv.drain()
    assert srv.pending() == 0
    total_found = 0
    for t, r in zip(tickets, reqs):
        v, f = results[t]
        ref_v, ref_f = _reference(srv.snapshot, r)
        np.testing.assert_array_equal(v, ref_v)
        np.testing.assert_array_equal(f, ref_f)
        total_found += int(ref_f.sum())
    s = srv.stats
    assert s.submitted == s.served == sum(r.size for r in reqs)
    assert s.found == total_found  # accumulated per chunk, padding excluded
    assert s.chunks == -(-sum(r.size for r in reqs) // 256)
    assert s.requests == len(reqs)


def test_scalar_and_empty_drain():
    keys, values = make_tree_data(100, seed=1)
    srv = BSTServer(keys, values, chunk_size=64)
    assert srv.drain() == {}
    v, f = srv.lookup(int(keys[5]))
    assert bool(f[0]) and int(v[0]) == int(values[5])


@pytest.mark.parametrize("name", sorted(PAPER_CONFIGS))
@pytest.mark.parametrize("use_kernel", [False, True])
def test_snapshot_swap_every_strategy(name, use_kernel):
    """bulk_insert/bulk_delete then lookups agree with search_reference
    through every paper strategy, kernel and reference paths alike."""
    keys, values = make_tree_data(500, seed=3)
    cfg = dataclasses.replace(PAPER_CONFIGS[name], use_kernel=use_kernel)
    srv = BSTServer(keys, values, cfg, chunk_size=128)

    ins_k = np.array([1, 3, 5, 7, int(keys[0]), int(keys[42])], np.int32)
    ins_v = np.array([10, 30, 50, 70, 999, 888], np.int32)
    del_k = keys[10:20]
    srv.apply_updates(insert_keys=ins_k, insert_values=ins_v, delete_keys=del_k)
    assert srv.stats.snapshot_swaps == 1

    rng = np.random.default_rng(4)
    probes = np.concatenate(
        [ins_k, del_k, rng.choice(np.concatenate([keys, keys + 1]), 300)]
    ).astype(np.int32)
    v, f = srv.lookup(probes)
    ref_v, ref_f = _reference(srv.snapshot, probes)
    np.testing.assert_array_equal(v, ref_v, err_msg=f"{name} kernel={use_kernel}")
    np.testing.assert_array_equal(f, ref_f, err_msg=f"{name} kernel={use_kernel}")

    # semantic spot-checks against the update stream itself
    kv = dict(zip(keys.tolist(), values.tolist()))
    for k in del_k.tolist():
        kv.pop(k)
    kv.update(dict(zip(ins_k.tolist(), ins_v.tolist())))
    got = dict(zip(probes.tolist(), v.tolist()))
    hit = dict(zip(probes.tolist(), f.tolist()))
    for k in ins_k.tolist():
        assert hit[k] and got[k] == kv[k]
    for k in del_k.tolist():
        assert not hit[k]


def test_per_op_busy_attribution_by_lanes():
    """Busy seconds attribute by the engine lanes each request occupied:
    range requests count their lo AND hi descent lanes, write/delete
    requests sharing a span split its time by key count, and per-op busy
    always sums to the span total (nothing double-booked or skewed)."""
    keys, values = make_tree_data(500, seed=11)
    srv = BSTServer(
        keys,
        values,
        EngineConfig(strategy="hrz", delta_capacity=64),
        chunk_size=128,
        scan_k=4,
    )
    rng = np.random.default_rng(2)
    q = rng.choice(keys, 100).astype(np.int32)
    lo = rng.choice(keys, 60).astype(np.int32)
    srv.submit(q)
    srv.submit_range(lo, (lo + 10).astype(np.int32), op="range_count")
    srv.drain()
    s = srv.stats
    assert s.per_op["lookup"].lanes == 100
    assert s.per_op["range_count"].lanes == 120  # lo||hi: 2 lanes per range
    assert s.lanes == 220
    assert sum(o.busy_s for o in s.per_op.values()) == pytest.approx(s.busy_s)
    assert s.per_op["range_count"].lanes_per_sec == pytest.approx(
        120 / s.per_op["range_count"].busy_s
    )

    srv.reset_stats()
    # a mixed write+delete span rides shared engine calls: time splits by
    # occupied lanes (30 write keys vs 10 delete keys -> exactly 3:1)
    srv.submit_write(
        np.arange(2001, 2031, dtype=np.int32), np.ones(30, np.int32)
    )
    srv.submit_delete(np.arange(2001, 2011, dtype=np.int32))
    srv.drain()
    s = srv.stats
    w, d = s.per_op["write"], s.per_op["delete"]
    assert w.lanes == 30 and d.lanes == 10 and s.lanes == 40
    assert w.busy_s + d.busy_s == pytest.approx(s.busy_s)
    assert w.busy_s == pytest.approx(3 * d.busy_s)


def test_swap_applies_to_pending_requests():
    """Requests drained after a swap see the new snapshot (documented)."""
    keys, values = make_tree_data(300, seed=9)
    srv = BSTServer(keys, values, chunk_size=64)
    absent = np.array([1], np.int32)  # odd -> not in the seed tree
    t = srv.submit(absent)
    srv.apply_updates(insert_keys=absent, insert_values=np.array([42], np.int32))
    v, f = srv.drain()[t]
    assert bool(f[0]) and int(v[0]) == 42
