"""Per-architecture smoke tests (reduced configs, CPU) + serving equivalence.

The assignment requires: instantiate a REDUCED config of the same family and
run one forward/train step asserting output shapes + no NaNs.  The decode
consistency test additionally proves the KV/SSM/cross caches are exact.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import model as M
from repro.models.config import SHAPES, cell_is_runnable, input_specs


def _inputs(cfg, B, S, key=0):
    tokens = jax.random.randint(jax.random.key(key), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(key + 1), (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        flen = S if cfg.family == "encdec" else cfg.frontend_len
        fe = (
            jax.random.normal(jax.random.key(key + 2), (B, flen, cfg.d_model)) * 0.02
        ).astype(cfg.param_dtype)
    return tokens, labels, fe


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    B, S = 2, 32
    params = M.init_params(cfg, jax.random.key(0))
    tokens, labels, fe = _inputs(cfg, B, S)
    loss, metrics = M.forward_train(cfg, params, tokens, labels, fe)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one optimizer step moves the loss
    from repro.training.train_loop import TrainConfig, init_train_state, make_train_step

    tcfg = TrainConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10)
    state = init_train_state(cfg, tcfg, jax.random.key(0))
    step = make_train_step(cfg, tcfg)
    if cfg.frontend is not None:
        state2, m1 = step(state, tokens, labels, fe)
        _, m2 = step(state2, tokens, labels, fe)
    else:
        state2, m1 = step(state, tokens, labels)
        _, m2 = step(state2, tokens, labels)
    assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"])
    assert float(m2["loss"]) < float(m1["loss"])  # same batch: must improve


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = smoke_config(arch)
    B, S, EXTRA = 2, 24, 3
    params = M.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S + EXTRA), 0, cfg.vocab_size)
    _, _, fe = _inputs(cfg, B, S)
    logits, state = M.prefill(cfg, params, toks[:, :S], fe, max_len=S + EXTRA)
    assert logits.shape == (B, cfg.vocab_size)
    dec = [logits]
    for t in range(EXTRA):
        lg, state = M.decode_step(cfg, params, toks[:, S + t : S + t + 1], state)
        dec.append(lg)
    for t in range(EXTRA + 1):
        ref, _ = M.prefill(cfg, params, toks[:, : S + t], fe, max_len=S + EXTRA)
        np.testing.assert_allclose(
            np.asarray(dec[t], np.float32), np.asarray(ref, np.float32),
            atol=2e-3, rtol=2e-3,
        )


def test_full_configs_match_assignment():
    """The exact published dimensions from the assignment table."""
    want = {
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "hymba_1p5b": (32, 1600, 25, 5, 5504, 32001),
        "internlm2_1p8b": (24, 2048, 16, 8, 8192, 92544),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "tinyllama_1p1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen3_1p7b": (28, 2048, 16, 8, 6144, 151936),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mamba2_1p3b": (48, 2048, 0, 0, 0, 50280),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
    }
    for arch, (L, D, H, KV, F, V) in want.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == (L, D, H, KV, F, V), (arch, got)
    assert get_config("mixtral_8x7b").n_experts == 8
    assert get_config("mixtral_8x7b").top_k == 2
    assert get_config("mamba2_1p3b").ssm_state == 128
    assert get_config("hymba_1p5b").ssm_state == 16
    assert get_config("qwen3_1p7b").qk_norm


def test_cell_runnability_matrix():
    """40 cells: 34 runnable + 6 documented long_500k skips."""
    runnable, skipped = 0, []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = cell_is_runnable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped.append((arch, shape.name))
    assert runnable + len(skipped) == 40
    assert len(skipped) == 6
    assert all(s == "long_500k" for _, s in skipped)
    long_runners = {a for a in ARCH_IDS if cell_is_runnable(get_config(a), SHAPES["long_500k"])[0]}
    assert long_runners == {"hymba_1p5b", "mamba2_1p3b", "mixtral_8x7b", "mixtral_8x22b"}


def test_input_specs_no_allocation():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert all(isinstance(s, jax.ShapeDtypeStruct) for s in specs.values())
            assert specs["tokens"].shape[0] == shape.global_batch


def test_param_count_formula_matches_init():
    """n_params() (used for MODEL_FLOPS) must match actual init'd trees."""
    for arch in ARCH_IDS:
        cfg = smoke_config(arch)
        params = M.init_params(cfg, jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.n_params()
        assert abs(actual - predicted) / actual < 0.02, (arch, actual, predicted)
