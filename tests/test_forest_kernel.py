"""Acceptance tests for the forest-batched flat kernel (DESIGN.md §2).

Every strategy's descent must lower to exactly ONE ``pallas_call`` over one
flat level-major tree operand, and the results must be bit-identical to
``search_reference`` -- including at heights the old per-level-operand
kernel was never exercised at (> 12).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import plans, tree as T
from repro.core.engine import BSTEngine, PAPER_CONFIGS, EngineConfig
from repro.data.keysets import make_tree_data
from repro.kernels import ops


def _queries(keys, size, seed=0):
    rng = np.random.default_rng(seed)
    return rng.choice(np.concatenate([keys, keys + 1]), size=size).astype(np.int32)


def _nested_jaxprs(value):
    from jax._src import core as jcore

    if isinstance(value, jcore.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jcore.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _nested_jaxprs(v)


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in _nested_jaxprs(v):
                n += _count_pallas_calls(sub)
    return n


# ----------------------------------------------------------------- bit-ident.
@pytest.mark.parametrize("height", [4, 9, 13, 16])
def test_forest_kernel_matches_reference_deep_trees(height):
    """Heights up to 16 -- the per-level-operand kernel stopped at ~12."""
    n_keys = (1 << (height + 1)) - 1  # perfect tree, no sentinel padding
    keys, values = make_tree_data(n_keys, seed=height)
    tree = T.build_tree(keys, values)
    assert tree.height == height
    q = _queries(keys, 512, seed=height)
    ref_v, ref_f = T.search_reference(tree, jnp.asarray(q))
    v, f = ops.bst_search_forest(
        tree.keys[None], tree.values[None], jnp.asarray(q)[None], height=height
    )
    np.testing.assert_array_equal(np.asarray(v[0]), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(f[0]), np.asarray(ref_f))


def test_forest_kernel_shared_tree_rows():
    """dup layout: one operand row serves every query row bit-identically."""
    keys, values = make_tree_data(4095, seed=1)
    tree = T.build_tree(keys, values)
    q = _queries(keys, 1024, seed=2).reshape(4, 256)
    v, f = ops.bst_search_forest(
        tree.keys[None],
        tree.values[None],
        jnp.asarray(q),
        height=tree.height,
        shared_tree=True,
    )
    for row in range(4):
        ref_v, ref_f = T.search_reference(tree, jnp.asarray(q[row]))
        np.testing.assert_array_equal(np.asarray(v[row]), np.asarray(ref_v))
        np.testing.assert_array_equal(np.asarray(f[row]), np.asarray(ref_f))


# ----------------------------------------------------- one pallas_call per plan
@pytest.mark.parametrize("strategy,n_trees,mapping", [
    ("hrz", 1, "queue"),
    ("dup", 4, "queue"),
    ("hyb", 4, "queue"),
    ("hyb", 4, "direct"),
])
def test_single_pallas_call_per_strategy(strategy, n_trees, mapping):
    """hrz, dup and hyb all descend through exactly one pallas_call."""
    keys, values = make_tree_data(2047, seed=5)
    tree = T.build_tree(keys, values)
    plan = plans.make_plan(
        tree, strategy=strategy, n_trees=n_trees, mapping=mapping
    )
    q = _queries(keys, 256, seed=6)

    def run(queries):
        return plans.execute_plan(plan, queries, use_kernel=True, interpret=True)

    jaxpr = jax.make_jaxpr(run)(jnp.asarray(q))
    assert _count_pallas_calls(jaxpr.jaxpr) == 1, (strategy, mapping)

    ref_v, ref_f = T.search_reference(tree, jnp.asarray(q))
    v, f = run(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(ref_f))


def test_kernel_engine_height13_all_strategies():
    """Every paper preset through the kernel path on a height-13 tree."""
    keys, values = make_tree_data((1 << 14) - 1, seed=9)
    tree = T.build_tree(keys, values)
    assert tree.height == 13
    q = _queries(keys, 256, seed=10)
    ref_v, ref_f = T.search_reference(tree, jnp.asarray(q))
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, dataclasses.replace(cfg, use_kernel=True))
        v, f = eng.lookup(q)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(ref_v), err_msg=name)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(ref_f), err_msg=name)


@pytest.mark.parametrize("op", ["predecessor", "range_count", "range_scan"])
def test_single_pallas_call_per_ordered_op(op):
    """Every ordered op lowers through exactly one pallas_call too --
    range ops descend the concatenated lo||hi batch (DESIGN.md §6)."""
    keys, values = make_tree_data(2047, seed=5)
    tree = T.build_tree(keys, values)
    plan = plans.make_plan(tree, strategy="hyb", n_trees=4)
    q = _queries(keys, 256, seed=6)
    args = (jnp.asarray(q),)
    if op in plans.RANGE_OPS:
        args = (jnp.asarray(q), jnp.asarray(q + 64))

    def run(*a):
        return plans.ordered_query(plan, op, *a, use_kernel=True, interpret=True)

    jaxpr = jax.make_jaxpr(run)(*args)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1, op


@pytest.mark.parametrize("height", [4, 13])
def test_ordered_kernel_matches_ordered_reference(height):
    """The kernel's ordered outputs (pred/succ ancestors, rank) are
    bit-identical to the jnp oracle at shallow and deep heights."""
    n_keys = (1 << (height + 1)) - 1
    keys, values = make_tree_data(n_keys, seed=height)
    tree = T.build_tree(keys, values)
    q = _queries(keys, 512, seed=height)
    ref = T.search_reference_ordered(tree, jnp.asarray(q))
    out = ops.bst_ordered_forest(
        tree.keys[None], tree.values[None], jnp.asarray(q)[None], height=height
    )
    for name, want, got in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(
            np.asarray(got[0]), np.asarray(want), err_msg=name
        )


def test_ordered_kernel_inactive_lanes_identity():
    """Inactive lanes report the tracking identities (merge-safe fills)."""
    keys, values = make_tree_data(511, seed=3)
    tree = T.build_tree(keys, values)
    q = _queries(keys, 128, seed=4)
    act = np.zeros(128, bool)
    out = ops.bst_ordered_forest(
        tree.keys[None],
        tree.values[None],
        jnp.asarray(q)[None],
        height=tree.height,
        active=jnp.asarray(act)[None],
    )
    val, found, pk, pv, sk, sv, rank = (np.asarray(o[0]) for o in out)
    assert not found.any()
    assert (pk == T.NO_PRED_KEY).all() and (sk == T.NO_SUCC_KEY).all()
    assert (val == T.SENTINEL_VALUE).all() and (rank == 0).all()


def test_forest_kernel_active_mask():
    """Inactive lanes can neither hit nor leak values."""
    keys, values = make_tree_data(511, seed=3)
    tree = T.build_tree(keys, values)
    q = _queries(keys, 128, seed=4)
    rng = np.random.default_rng(7)
    act = rng.integers(0, 2, size=128).astype(bool)
    v, f = ops.bst_search_forest(
        tree.keys[None],
        tree.values[None],
        jnp.asarray(q)[None],
        height=tree.height,
        active=jnp.asarray(act)[None],
    )
    ref_v, ref_f = T.search_reference(tree, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(f[0]), np.asarray(ref_f) & act)
    np.testing.assert_array_equal(
        np.asarray(v[0])[act], np.asarray(ref_v)[act]
    )
    assert np.all(np.asarray(v[0])[~act] == T.SENTINEL_VALUE)
