"""Cycle-accurate simulator vs the paper's claims (the reproduction gate).

These are the quantitative checks EXPERIMENTS.md cites:
  * Dup8 ~ 8x Hrz with ~16 keys/cycle (paper: "up to 8X", "nearly 16/cyc")
  * DupN speedups are key-set independent (paper Fig.7 discussion)
  * Hybrid impls converge to Hrz on the Equal set (same port count)
  * Split creates no stalls; hybrids reach their port-limit throughput
  * queue mapping beats direct mapping on Random (paper: 32-39%)
"""

import numpy as np
import pytest

from repro.core import tree as T
from repro.core.cyclesim import run_paper_matrix, simulate
from repro.core.engine import PAPER_CONFIGS
from repro.data.keysets import make_key_sets, make_tree_data


@pytest.fixture(scope="module")
def matrix():
    keys, values = make_tree_data((1 << 14) - 1, seed=0)
    tree = T.build_tree(keys, values)
    sets = make_key_sets(tree, 16384)
    return run_paper_matrix(tree, sets)


def speedup(row, impl):
    return row["Hrz"].cycles / row[impl].cycles


def test_hrz_baseline_throughput(matrix):
    for row in matrix.values():
        assert abs(row["Hrz"].keys_per_cycle - 2.0) < 0.05  # dual-port


def test_dup_speedups_constant_across_keysets(matrix):
    for impl, expect in (("Dup4", 4.0), ("Dup8", 8.0)):
        sps = [speedup(row, impl) for row in matrix.values()]
        for sp in sps:
            assert abs(sp - expect) < 0.15, (impl, sp)
        assert max(sps) - min(sps) < 0.02  # key-set independent


def test_dup8_reaches_16_keys_per_cycle(matrix):
    for row in matrix.values():
        assert row["Dup8"].keys_per_cycle > 15.5


def test_hybrid_converges_to_hrz_on_equal(matrix):
    row = matrix["equal"]
    for impl in ("Hyb4", "Hyb4q", "Hyb8", "Hyb8q"):
        assert abs(speedup(row, impl) - 1.0) < 0.05, impl


def test_split_is_stall_free_for_queue(matrix):
    row = matrix["split"]
    assert row["Hyb4q"].stall_cycles == 0
    assert row["Hyb8q"].stall_cycles == 0
    assert speedup(row, "Hyb8q") > 7.8  # port-limit throughput
    assert speedup(row, "Hyb4q") > 3.9


def test_split_stall_free_direct_mapping(matrix):
    """Bit-reversed round-robin makes even direct mapping conflict-free."""
    row = matrix["split"]
    assert row["Hyb8"].stall_cycles == 0
    assert speedup(row, "Hyb8") > 7.8


def test_queue_beats_direct_on_random(matrix):
    row = matrix["random"]
    for n in (4, 8):
        d, q = row[f"Hyb{n}"], row[f"Hyb{n}q"]
        gain = d.cycles / q.cycles - 1
        assert gain > 0.25, (n, gain)  # paper band: 32-39%
        assert q.stall_cycles < d.stall_cycles


def test_fig7_relative_ordering_golden(matrix):
    """Golden pin of the paper's Fig. 7 relative-throughput ordering on the
    random key set: Hrz < Hyb4 < Dup4 < Hyb8q <= Dup8.  Kernel/engine work
    must not silently diverge the cycle model from the paper's story (the
    hybrids trade stalls for memory; duplication buys stall-free ports)."""
    row = matrix["random"]
    sp = {impl: speedup(row, impl) for impl in row}
    assert sp["Hrz"] == pytest.approx(1.0)
    assert sp["Hrz"] < sp["Hyb4"] < sp["Dup4"] < sp["Hyb8q"] <= sp["Dup8"], sp
    # and the queue mapping sits between its direct twin and the replica
    # ceiling for both widths, as in the figure
    assert sp["Hyb4"] < sp["Hyb4q"] < sp["Dup4"], sp
    assert sp["Hyb8"] < sp["Hyb8q"] <= sp["Dup8"], sp


def test_stall_accounting_no_double_count():
    """A stalled cycle is one where the frontend cannot FETCH: the chunk's
    entry cycle is not a stall, and the cycle the last deferred key places
    is not either (the frontend resumes the same cycle).  Pinned trace for
    16 keys that all route to subtree 0 of a Hyb4q (chunk 8, capacity 8):

      cycle 1: chunk 1 (8 keys) enters, all place          -> no stall
      cycle 2: drain 2, chunk 2 enters, 2 place, 6 defer   -> no stall (fetch!)
      cycle 3: drain 2, 2 of 6 pending place               -> stall
      cycle 4: drain 2, 2 of 4 pending place               -> stall
      cycle 5: drain 2, last 2 place, frontend resumes     -> no stall

    The pre-fix accounting ALSO counted cycle 2 (entry + next-pass double
    book), reporting 3 stalls for 2 blocked cycles."""
    keys, values = make_tree_data((1 << 10) - 1, seed=0)
    tree = T.build_tree(keys, values)
    q = np.zeros(16, np.int32)  # below every stored key: leftmost subtree
    r = simulate(PAPER_CONFIGS["Hyb4q"], tree, q)
    assert r.stall_cycles == 2, r
    # direct mapping stalls more (slot conflicts), never less
    d = simulate(PAPER_CONFIGS["Hyb4"], tree, q)
    assert d.stall_cycles >= r.stall_cycles
    # one chunk of 16 fits Hyb8q's capacity-16 buffers outright
    assert simulate(PAPER_CONFIGS["Hyb8q"], tree, q).stall_cycles == 0


def test_pipeline_latency_accounting():
    keys, values = make_tree_data(255, seed=1)
    tree = T.build_tree(keys, values)
    # a single chunk must drain in ~latency cycles, not throughput time
    q = np.asarray(tree.keys)[: 16][np.asarray(tree.keys)[:16] != T.SENTINEL_KEY]
    r = simulate(PAPER_CONFIGS["Hyb8q"], tree, q.astype(np.int32))
    assert r.cycles <= 3 * (tree.height + 2)
