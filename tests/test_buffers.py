"""Property tests for the direct/queue dispatch primitives (paper §II.C.3)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import buffers as B


@st.composite
def dispatch_case(draw):
    n_dest = draw(st.integers(1, 12))
    size = draw(st.integers(1, 200))
    capacity = draw(st.integers(1, 48))
    dest = draw(
        st.lists(st.integers(-1, n_dest - 1), min_size=size, max_size=size)
    )
    return np.array(dest, np.int32), n_dest, capacity


class TestQueueDispatch:
    @given(dispatch_case())
    @settings(max_examples=60, deadline=None)
    def test_invariants(self, case):
        dest, n_dest, cap = case
        plan = B.queue_dispatch(jnp.asarray(dest), n_dest, cap)
        buffers = np.asarray(plan.buffers)
        kept = np.asarray(plan.kept)
        counts = np.asarray(plan.counts)
        active = dest >= 0
        # 1. every kept item appears exactly once, in its own dest row
        items = buffers[buffers >= 0]
        assert len(items) == len(set(items.tolist())) == kept.sum()
        for d in range(n_dest):
            row = buffers[d][buffers[d] >= 0]
            assert all(dest[i] == d for i in row.tolist())
            # 2. FIFO: source order preserved within a buffer, densely packed
            occupied = buffers[d] >= 0
            assert not np.any(np.diff(np.where(occupied)[0]) > 1) or True
            assert sorted(row.tolist()) == row.tolist()
            # 3. dense packing from slot 0 (queue property)
            assert np.all(occupied[: counts[d]]) and not np.any(occupied[counts[d]:])
        # 4. overflow = active and not kept; only when fair share exceeded
        assert np.array_equal(np.asarray(plan.overflow), active & ~kept)
        # 5. an item overflows iff >= capacity same-dest items precede it
        for i in np.where(active)[0]:
            earlier = np.sum(dest[:i] == dest[i])
            assert kept[i] == (earlier < cap)

    @given(dispatch_case())
    @settings(max_examples=30, deadline=None)
    def test_queue_never_wastes_slots(self, case):
        """Paper claim: queue only overflows when the buffer is truly full."""
        dest, n_dest, cap = case
        plan = B.queue_dispatch(jnp.asarray(dest), n_dest, cap)
        counts = np.asarray(plan.counts)
        for i in np.where(np.asarray(plan.overflow))[0]:
            assert counts[dest[i]] == cap  # its buffer is completely full


class TestDirectDispatch:
    @given(dispatch_case())
    @settings(max_examples=60, deadline=None)
    def test_invariants(self, case):
        dest, n_dest, cap = case
        plan = B.direct_dispatch(jnp.asarray(dest), n_dest, cap)
        buffers = np.asarray(plan.buffers)
        kept = np.asarray(plan.kept)
        # every kept item sits at slot (index mod capacity) of its dest
        for d in range(n_dest):
            for slot, i in enumerate(buffers[d].tolist()):
                if i >= 0:
                    assert dest[i] == d and i % cap == slot

    @given(dispatch_case())
    @settings(max_examples=30, deadline=None)
    def test_direct_can_waste_slots_queue_cannot(self, case):
        """The paper's Fig.5-vs-Fig.6 property: at equal capacity the queue
        mapping keeps at least as many items as the direct mapping."""
        dest, n_dest, cap = case
        dq = B.queue_dispatch(jnp.asarray(dest), n_dest, cap)
        dd = B.direct_dispatch(jnp.asarray(dest), n_dest, cap)
        assert int(dq.kept.sum()) >= int(dd.kept.sum())


class TestRoundTrip:
    @given(dispatch_case())
    @settings(max_examples=30, deadline=None)
    def test_gather_combine_roundtrip(self, case):
        dest, n_dest, cap = case
        B_ = len(dest)
        items = jnp.arange(B_, dtype=jnp.int32) * 10 + 3
        plan = B.queue_dispatch(jnp.asarray(dest), n_dest, cap)
        per = B.gather_from_buffers(items, plan.buffers, fill_value=-7)
        back = B.combine_to_chunk(per, plan.buffers, B_, fill_value=-9)
        back = np.asarray(back)
        kept = np.asarray(plan.kept)
        items = np.asarray(items)
        assert np.array_equal(back[kept], items[kept])
        assert np.all(back[~kept] == -9)
