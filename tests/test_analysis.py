"""repro.analysis: lint rules, contracts, dead-code drift, runtime gate.

The regression heart of the suite: re-introduce the exact bug classes the
analyzer exists to catch (a tracer-bool leak, host ops under jit, a
delta-content-dependent shape that retraces per drain) and assert the
right pass flags each one -- then assert the real tree is clean and the
steady-state serve gate holds on every strategy.
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, deadcode, gate, invariants, lint, report, runtime


def _lint_src(tmp_path, src, name="case.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    hard, _soft = lint.lint_paths([str(p)], allowlist=None)
    return {v.rule for v in hard}, hard


# --------------------------------------------------------------------- lint
def test_lint_catches_tracer_leak(tmp_path):
    # The classic leak symptom: branching on a traced value.  Outside jit
    # it is a silent sync; inside it is TracerBoolConversionError.
    rules, _ = _lint_src(
        tmp_path,
        """
        import jax.numpy as jnp

        def route(x):
            y = jnp.abs(x)
            if y > 0:
                return y
            return x
        """,
    )
    assert "ANA001" in rules


def test_lint_catches_host_ops_under_jit(tmp_path):
    rules, hard = _lint_src(
        tmp_path,
        """
        import jax
        import numpy as np

        @jax.jit
        def bad(x):
            v = np.asarray(x)
            print(v)
            return x
        """,
    )
    assert "ANA002" in rules
    assert sum(v.rule == "ANA002" for v in hard) == 2  # np.asarray + print


def test_lint_catches_jit_in_loop_retrace(tmp_path):
    rules, _ = _lint_src(
        tmp_path,
        """
        import jax

        def drain(chunks):
            out = []
            for c in chunks:
                f = jax.jit(lambda v: v + 1)
                out.append(f(c))
            return out
        """,
    )
    assert "ANA004" in rules


def test_lint_catches_implicit_host_pull(tmp_path):
    rules, _ = _lint_src(
        tmp_path,
        """
        import jax.numpy as jnp

        def count(x):
            total = jnp.sum(x)
            return int(total)
        """,
    )
    assert "ANA005" in rules


def test_lint_catches_kernel_host_op(tmp_path):
    rules, _ = _lint_src(
        tmp_path,
        """
        import numpy as np

        def step_kernel(keys_ref, out_ref):
            out_ref[...] = np.asarray(keys_ref)
        """,
    )
    assert "ANA003" in rules


def test_lint_array_metadata_is_not_a_pull(tmp_path):
    # int(x.shape[0]) is host metadata, not a device sync.
    rules, _ = _lint_src(
        tmp_path,
        """
        import jax.numpy as jnp

        def pad(x):
            y = jnp.abs(x)
            n = int(y.shape[0])
            return n
        """,
    )
    assert "ANA005" not in rules


def test_lint_flags_unallowlisted_explicit_fetch(tmp_path):
    serving = tmp_path / "serving"
    serving.mkdir()
    p = serving / "hot.py"
    p.write_text("import jax\n\ndef pull(x):\n    return jax.device_get(x)\n")
    hard, _ = lint.lint_paths([str(p)], allowlist=None)
    assert {v.rule for v in hard} == {"ANA006"}


def test_hot_path_tree_is_lint_clean():
    hard, soft = lint.lint_paths(
        [
            "src/repro/core",
            "src/repro/kernels",
            "src/repro/serving",
            "src/repro/launch",
        ]
    )
    assert hard == [], report.render_all(hard)
    # the sanctioned syncs stay visible as allowlisted, not invisible
    assert {v.rule for v in soft} >= {"ANA006"}


# ---------------------------------------------------------- runtime detector
def test_compile_watch_catches_content_dependent_shape_retrace():
    # The PR4-era bug class: syncing the delta count and slicing to it
    # gives every drain a fresh shape -- a retrace per content change.
    f = jax.jit(lambda a: a * 2)
    f(jnp.arange(8))  # warm
    with runtime.compile_watch() as cw:
        f(jnp.arange(8))
    assert cw.count == 0, cw.messages()
    count = jnp.int32(5)
    with runtime.compile_watch() as cw:
        n = int(count)  # the content sync
        f(jnp.arange(8)[:n])  # content-dependent shape
    assert cw.count >= 1


def test_transfer_watch_counts_sanctioned_fetches():
    f = jax.jit(lambda a: a + 1)
    x = jnp.arange(4)
    f(x)  # warm
    with runtime.transfer_watch() as tw:
        got = runtime.device_fetch(f(x))
    np.testing.assert_array_equal(got, np.arange(4) + 1)
    assert tw.fetches == 1


def test_transfer_watch_blocks_implicit_host_to_device():
    f = jax.jit(lambda a: a + 1)
    f(jnp.arange(4))  # warm
    with runtime.transfer_watch():
        with pytest.raises(Exception, match="[Dd]isallowed.*transfer"):
            f(np.arange(4))  # numpy operand = implicit h2d under the guard


# ---------------------------------------------------------------- contracts
def test_contracts_pass_on_current_tree():
    errors = contracts.run_contracts()
    assert errors == [], report.render_all(errors)


def test_contract_rows_catch_output_drift():
    errors = []
    # lookup declares (values, found); a bare values row must fail
    contracts._check_outputs(
        "t", "lookup", (jax.ShapeDtypeStruct((8,), jnp.int32),), 8, 4, errors
    )
    assert errors
    errors = []
    # wrong dtype on found
    contracts._check_outputs(
        "t",
        "lookup",
        (
            jax.ShapeDtypeStruct((8,), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ),
        8,
        4,
        errors,
    )
    assert errors


def test_invariants_reject_bad_configs():
    with pytest.raises(ValueError):
        invariants.check_delta_config(8, 9)
    with pytest.raises(ValueError):
        invariants.check_chunk_divides(100, 8, "model")
    with pytest.raises(ValueError):
        invariants.check_forest_nodes(30, 4)
    assert invariants.split_level_for(4) == 2


# ----------------------------------------------------------------- deadcode
def test_deadcode_flags_unreachable_module(tmp_path):
    pkg = tmp_path / "src" / "repro"
    (pkg / "launch").mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "launch" / "__init__.py").write_text("")
    (pkg / "launch" / "serve.py").write_text("from repro import used\n")
    (pkg / "used.py").write_text("")
    (pkg / "unused.py").write_text("")
    classes = deadcode.dead_modules(str(tmp_path))
    assert classes == {"repro.unused": "DEAD"}


def test_deadcode_follows_dynamic_registry_imports(tmp_path):
    pkg = tmp_path / "src" / "repro"
    (pkg / "configs").mkdir(parents=True)
    (pkg / "launch").mkdir()
    (pkg / "__init__.py").write_text("")
    (pkg / "launch" / "__init__.py").write_text("")
    (pkg / "launch" / "serve.py").write_text("import repro.configs\n")
    (pkg / "configs" / "__init__.py").write_text(
        "import importlib\n"
        "def load(name):\n"
        "    return importlib.import_module(f'repro.configs.{name}')\n"
    )
    (pkg / "configs" / "tiny.py").write_text("")
    classes = deadcode.dead_modules(str(tmp_path))
    assert classes == {}  # tiny.py kept alive through the registry


def test_deadcode_quarantine_covers_real_tree():
    errors, classes = deadcode.report_dead(".")
    assert errors == [], report.render_all(errors)
    # the quarantined seed modules stay tracked, not silently dead
    assert set(classes) == set(deadcode.load_quarantine())


# ------------------------------------------------------------ runtime gate
@pytest.mark.parametrize("strategy", ["hrz", "dup", "hyb"])
def test_serve_gate_steady_state_clean(strategy):
    errors = gate.serve_gate(strategy, n_chunks=3)
    assert errors == [], report.render_all(errors)


# ---------------------------------------------------------------------- CLI
def test_cli_exit_codes(tmp_path):
    from repro.analysis.__main__ import main

    bad = tmp_path / "hot.py"
    bad.write_text(
        "import jax.numpy as jnp\n\ndef f(x):\n"
        "    return int(jnp.sum(x))\n"
    )
    assert main([str(bad), "--skip-contracts", "--repo-root", "."]) == 1
    clean = tmp_path / "ok.py"
    clean.write_text("def f(x):\n    return x\n")
    out = tmp_path / "report.json"
    assert (
        main(
            [str(clean), "--skip-contracts", "--repo-root", ".",
             "--report", str(out)]
        )
        == 0
    )
    assert out.exists()
