"""Property-based differential harness for the live write path (DESIGN.md §7).

Random op sequences -- insert / delete / re-insert / lookup / predecessor /
successor / range_count / range_scan -- run through the delta-buffered
engine and are checked BIT-FOR-BIT against a plain Python ``dict`` +
``sorted`` oracle, preserving submission order (a read sees exactly the
writes before it).  Coverage axes:

  * hrz / dup / hyb strategies, reference AND Pallas-kernel descent paths;
  * pre-compaction (live buffer) and post-compaction (fresh snapshot)
    states -- every sequence is re-probed right after a forced ``compact()``;
  * the ≥ 500-op mixed-stream acceptance gate through ``BSTServer``'s typed
    write/delete request kinds, per strategy;
  * the SHARDED serving paths (DESIGN.md §9): on a forced 8-device host
    (the ``multi_device_host`` conftest fixture -- XLA device counts must
    precede jax init, so the body runs subprocess-side), a sharded
    ``BSTServer`` drains the same submission sequence as a single-chip
    server and must match BIT-FOR-BIT, for hrz / dup / hyb x kernel /
    reference descent x pre-/post-compaction, live writes included; plus
    a ≥ 500-op mixed read/write soak per mix ratio that cross-checks the
    per-op ``OpStats`` lane accounting and the ``keys_per_sec`` /
    ``lanes_per_sec`` invariants against the submitted op counts.

Runs under real hypothesis or the deterministic ``_hypothesis_fallback``
shim alike (the strategies stick to the shim's subset).  Reads are flushed
in write-bounded spans at fixed padded shapes so each engine epoch compiles
once; correctness never depends on the batching.
"""

import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import BSTEngine, EngineConfig
from repro.data.keysets import make_tree_data
from repro.serving import BSTServer

KEYSPACE = 500  # small universe -> plenty of overwrites / re-inserts
SCAN_K = 4
PROBE_PAD = 32  # fixed read-span batch shape (one compile per op kind)
WRITE_PAD = 16  # fixed write-span batch shape

READ_OPS = ("lookup", "predecessor", "successor", "range_count", "range_scan")
ALL_OPS = ("insert", "delete") + READ_OPS

SENT_K = np.iinfo(np.int32).max
NO_PRED = np.iinfo(np.int32).min


def op_stream(min_size, max_size):
    return st.lists(
        st.tuples(
            st.sampled_from(ALL_OPS),
            st.integers(1, KEYSPACE),
            st.integers(0, 10**6),
            st.integers(0, 40),  # range span
        ),
        min_size=min_size,
        max_size=max_size,
    )


# ------------------------------------------------------------------ oracle
def oracle_answer(kv, op, q, span):
    """The Python dict + sorted ground truth for one read op."""
    sk = sorted(kv)
    if op == "lookup":
        return (kv.get(q, -1) if q in kv else -1, q in kv)
    if op == "predecessor":
        cands = [x for x in sk if x <= q]
        if not cands:
            return (NO_PRED, -1, False)
        return (cands[-1], kv[cands[-1]], True)
    if op == "successor":
        cands = [x for x in sk if x >= q]
        if not cands:
            return (SENT_K, -1, False)
        return (cands[0], kv[cands[0]], True)
    in_range = [x for x in sk if q <= x <= q + span]
    if op == "range_count":
        return (len(in_range),)
    head = in_range[:SCAN_K]
    keys = head + [SENT_K] * (SCAN_K - len(head))
    vals = [kv[x] for x in head] + [-1] * (SCAN_K - len(head))
    return (keys, vals, min(len(in_range), SCAN_K))


def check_read(name, kv, op, q, span, got):
    exp = oracle_answer(kv, op, q, span)
    ctx = f"{name}: {op}({q}, span={span})"
    if op == "lookup":
        val, found = got
        assert bool(found) == exp[1], ctx
        if exp[1]:
            assert int(val) == exp[0], ctx
    elif op in ("predecessor", "successor"):
        key, val, ok = got
        assert bool(ok) == exp[2], ctx
        assert int(key) == exp[0], f"{ctx}: key {int(key)} != {exp[0]}"
        if exp[2]:
            assert int(val) == exp[1], ctx
    elif op == "range_count":
        assert int(got) == exp[0], f"{ctx}: count {int(got)} != {exp[0]}"
    else:
        keys, vals, taken = got
        assert int(taken) == exp[2], ctx
        assert np.asarray(keys).tolist() == exp[0], ctx
        assert np.asarray(vals).tolist() == exp[1], ctx


# ----------------------------------------------------------------- driving
def flush_reads(name, eng, kv, reads):
    """Evaluate a read span at fixed padded shapes, checking each lane."""
    by_op = {}
    for op, q, span in reads:
        by_op.setdefault(op, []).append((q, span))
    for op, items in by_op.items():
        qs = np.array([q for q, _ in items], np.int32)
        spans = np.array([s for _, s in items], np.int32)
        pad = PROBE_PAD - qs.size
        qp = np.pad(qs, (0, pad), mode="edge")
        sp = np.pad(spans, (0, pad), mode="edge")
        if op in ("range_count", "range_scan"):
            res = eng.query(op, qp, qp + sp, k=SCAN_K)
        else:
            res = eng.query(op, qp)
        cols = res if isinstance(res, tuple) else (res,)
        for i, (q, span) in enumerate(items):
            lane = tuple(np.asarray(c)[i] for c in cols)
            check_read(name, kv, op, q, span, lane if len(lane) > 1 else lane[0])


def flush_writes(eng, pending):
    """Apply a write span through the device ingest at a fixed jit shape."""
    keys = np.array([k for k, _, _ in pending], np.int32)
    vals = np.array([v for _, v, _ in pending], np.int32)
    dels = np.array([d for _, _, d in pending], bool)
    pad = (-keys.size) % WRITE_PAD
    valid = np.ones(keys.size + pad, bool)
    if pad:
        valid[keys.size:] = False
        keys, vals, dels = (np.pad(a, (0, pad)) for a in (keys, vals, dels))
    eng.apply_ops(keys, vals, dels, valid)


def run_stream(name, eng, kv, ops):
    """One submission-ordered pass: write spans flush before the next read."""
    reads, writes = [], []
    for op, key, value, span in ops:
        if op in ("insert", "delete"):
            if reads:
                flush_reads(name, eng, kv, reads)
                reads = []
            writes.append((key, value, op == "delete"))
            if op == "delete":
                kv.pop(key, None)
            else:
                kv[key] = value
            if len(writes) == WRITE_PAD:
                flush_writes(eng, writes)
                writes = []
        else:
            if writes:
                flush_writes(eng, writes)
                writes = []
            reads.append((op, key, span))
            if len(reads) == PROBE_PAD:
                flush_reads(name, eng, kv, reads)
                reads = []
    if writes:
        flush_writes(eng, writes)
    if reads:
        flush_reads(name, eng, kv, reads)


def probe_all_ops(name, eng, kv, rng):
    """One fixed probe batch over every op kind (pre/post-compaction pin)."""
    qs = rng.integers(1, KEYSPACE + 60, PROBE_PAD).astype(np.int32)
    reads = [(op, int(q), int(q) % 37) for op in READ_OPS for q in qs[:6]]
    flush_reads(name, eng, kv, reads)


# The engines persist across hypothesis examples: each example extends the
# same live stream (state evolves through buffer fills and compactions),
# and compile costs amortize.  The oracle dict travels with its engine.
_ENGINES = {}


def _get_engine(name, cfg):
    if name not in _ENGINES:
        keys, values = make_tree_data(120, seed=zlib.crc32(name.encode()) % 97, spacing=3)
        eng = BSTEngine(keys, values, cfg)
        _ENGINES[name] = (eng, dict(zip(keys.tolist(), values.tolist())))
    return _ENGINES[name]


REF_CONFIGS = {
    "hrz": EngineConfig(strategy="hrz", delta_capacity=48, delta_high_water=40),
    "dup4": EngineConfig(
        strategy="dup", n_trees=4, delta_capacity=48, delta_high_water=40
    ),
    "hyb4q": EngineConfig(
        strategy="hyb", n_trees=4, mapping="queue",
        delta_capacity=48, delta_high_water=40,
    ),
}


@given(op_stream(30, 60), st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_engine_differential_ref(ops, seed):
    """Random op streams == dict oracle, all strategies, reference path."""
    rng = np.random.default_rng(seed % 2**32)
    for name, cfg in REF_CONFIGS.items():
        eng, kv = _get_engine(name, cfg)
        run_stream(name, eng, kv, ops)
        probe_all_ops(name, eng, kv, rng)


def test_engine_differential_ref_post_compaction():
    """The same engines, probed immediately after a forced compaction."""
    rng = np.random.default_rng(7)
    for name, cfg in REF_CONFIGS.items():
        eng, kv = _get_engine(name, cfg)
        run_stream(name, eng, kv, [("insert", 17, 1700, 0), ("delete", 18, 0, 0)])
        kv[17] = 1700
        kv.pop(18, None)
        probe_all_ops(name + "/pre", eng, kv, rng)
        eng.compact()
        assert eng.pending_writes() == 0
        probe_all_ops(name + "/post", eng, kv, rng)


@given(op_stream(14, 24), st.integers(0, 2**31 - 1))
@settings(max_examples=2, deadline=None)
def test_engine_differential_kernel(ops, seed):
    """The Pallas forest-kernel path (interpret mode): same differential,
    shorter streams -- the kernel is exercised per span, pre- and (via the
    buffer filling up) post-compaction."""
    rng = np.random.default_rng(seed % 2**32)
    for name, strategy, n in (("khrz", "hrz", 1), ("kdup4", "dup", 4)):
        cfg = EngineConfig(
            strategy=strategy, n_trees=n, use_kernel=True,
            delta_capacity=32, delta_high_water=28,
        )
        eng, kv = _get_engine(name, cfg)
        run_stream(name, eng, kv, ops)
        probe_all_ops(name, eng, kv, rng)


def test_engine_differential_kernel_hyb_post_compaction():
    """Hybrid through the kernel path, pre/post explicit compaction."""
    cfg = EngineConfig(
        strategy="hyb", n_trees=4, mapping="queue", use_kernel=True,
        delta_capacity=32, delta_high_water=28,
    )
    eng, kv = _get_engine("khyb4q", cfg)
    rng = np.random.default_rng(11)
    ops = [
        ("insert", 7, 70, 0), ("lookup", 7, 0, 0), ("delete", 7, 0, 0),
        ("lookup", 7, 0, 0), ("insert", 7, 71, 0),  # re-insert
        ("predecessor", 8, 0, 0), ("range_scan", 1, 0, 39),
    ]
    run_stream("khyb4q", eng, kv, ops)
    probe_all_ops("khyb4q/pre", eng, kv, rng)
    eng.compact()
    probe_all_ops("khyb4q/post", eng, kv, rng)


# ------------------------------------------------- adversarial hyb skew
def _assert_all_ops_match(tag, eng, kv, q, spans):
    """Every read op over the full batch, each lane against the module's
    one dict+sorted oracle (``oracle_answer`` via ``check_read``)."""
    for op in READ_OPS:
        if op in ("range_count", "range_scan"):
            got = eng.query(op, q, q + spans, k=SCAN_K)
        else:
            got = eng.query(op, q)
        cols = got if isinstance(got, tuple) else (got,)
        arrs = [np.asarray(c) for c in cols]
        for i in range(q.size):
            lane = tuple(a[i] for a in arrs)
            check_read(
                f"{tag}", kv, op, int(q[i]), int(spans[i]),
                lane if len(lane) > 1 else lane[0],
            )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=2, deadline=None)
def test_hyb_adversarial_skew_replay(seed):
    """Worst-case hybrid skew: every query routes to vertical subtree 0,
    overflowing the per-subtree dispatch buffers so most lanes resolve
    through the stall-round replay (in-kernel on the Pallas path,
    DESIGN.md §8).  Both mappings and both paths must stay bit-identical
    to the dict+sorted oracle, with a live delta buffer, pre- and
    post-compaction."""
    from repro.core import plans as plans_lib
    from repro.core import tree as tree_lib

    rng = np.random.default_rng(seed % 2**32)
    keys, values = make_tree_data(150, seed=13, spacing=3)
    engines = {
        f"{mapping}/kernel={uk}": BSTEngine(
            keys,
            values,
            EngineConfig(
                strategy="hyb", n_trees=4, mapping=mapping, use_kernel=uk,
                delta_capacity=32, delta_high_water=28,
            ),
        )
        for mapping, uk in (
            ("queue", False),
            ("queue", True),
            ("direct", False),
            ("direct", True),
        )
    }
    kv = dict(zip(keys.tolist(), values.tolist()))

    any_eng = next(iter(engines.values()))
    # every key strictly below the root's left child routes left-left:
    # vertical subtree 0 (split_level 2 -> the register layer is the top
    # two levels of the flat operand)
    bound = int(np.asarray(any_eng.tree.keys)[1])
    B = 600
    q = rng.integers(1, bound, B).astype(np.int32)
    dest, _, found = tree_lib.register_layer_route(any_eng.tree, q, 2)
    assert np.all((np.asarray(dest) == 0) | np.asarray(found))
    # the scenario must actually overflow: one subtree receives a whole
    # chunk while its buffer holds only the slack-scaled fair share
    plan = any_eng.plan
    assert B > plans_lib.hyb_capacity(plan, B)  # reference-path granularity
    assert 512 > plans_lib.hyb_capacity(plan, 512)  # kernel block_q chunks

    spans = rng.integers(0, 30, B).astype(np.int32)
    wk = rng.choice(np.arange(1, bound, dtype=np.int32), 24, replace=False)
    wv = rng.integers(0, 10**6, 24).astype(np.int32)
    wd = rng.integers(0, 3, 24) == 0
    for tag, eng in engines.items():
        eng.apply_ops(wk, wv, wd)
    for k_, v_, d_ in zip(wk.tolist(), wv.tolist(), wd.tolist()):
        if d_:
            kv.pop(k_, None)
        else:
            kv[k_] = v_

    for tag, eng in engines.items():
        assert eng.pending_writes() > 0  # the delta buffer rides the replay
        _assert_all_ops_match(f"{tag}/pre", eng, kv, q, spans)
        eng.compact()
        _assert_all_ops_match(f"{tag}/post", eng, kv, q, spans)


# ------------------------------------------------------- server acceptance
@pytest.mark.parametrize("name", sorted(REF_CONFIGS))
def test_server_mixed_stream_500_ops(name):
    """≥ 500 mixed ops (90/10 read/write) through BSTServer's typed write
    request kinds, drained in chunks, bit-identical to the oracle across
    every strategy -- the DESIGN.md §7 acceptance gate."""
    cfg = REF_CONFIGS[name]
    keys, values = make_tree_data(150, seed=3, spacing=3)
    srv = BSTServer(keys, values, cfg, chunk_size=64, scan_k=SCAN_K)
    kv = dict(zip(keys.tolist(), values.tolist()))
    rng = np.random.default_rng(zlib.crc32(name.encode()) % 2**31)

    n_ops = 520
    kinds = rng.choice(
        np.array(ALL_OPS), n_ops, p=[0.06, 0.04, 0.35, 0.15, 0.15, 0.15, 0.10]
    )
    tickets = []  # (ticket, op, key, span, kv-at-submit-time)
    for i, op in enumerate(kinds.tolist()):
        q = int(rng.integers(1, KEYSPACE))
        span = int(rng.integers(0, 40))
        if op == "insert":
            v = int(rng.integers(0, 10**6))
            t = srv.submit_write(q, v)
            kv[q] = v
            tickets.append((t, op, q, span, None))
        elif op == "delete":
            t = srv.submit_delete(q)
            kv.pop(q, None)
            tickets.append((t, op, q, span, None))
        else:
            if op in ("range_count", "range_scan"):
                t = srv.submit_range(q, q + span, op=op)
            else:
                t = srv.submit(q, op=op)
            tickets.append((t, op, q, span, dict(kv)))
        if (i + 1) % 50 == 0 or i == n_ops - 1:
            results = srv.drain()
            for t, top, tq, tspan, snap in tickets:
                got = results[t]
                if top in ("insert", "delete"):
                    assert int(got[0]) == 1
                    continue
                lane = tuple(np.asarray(c)[0] for c in got)
                check_read(
                    f"{name}/server", snap, top, tq, tspan,
                    lane if len(lane) > 1 else lane[0],
                )
            tickets = []
    assert srv.stats.updates > 0
    assert srv.stats.compactions > 0, "stream must cross the high-water mark"
    assert srv.pending() == 0


# --------------------------------------------------- sharded serving paths
def test_sharded_differential_all_strategies(multi_device_host):
    """Sharded == single-chip, bit for bit, on the same op sequence.

    A sharded BSTServer (forced 8-device host) and a single-chip server
    take IDENTICAL submissions -- mixed writes, deletes and every read op
    -- and every drained column must match exactly, for hrz / dup / hyb,
    reference and Pallas-kernel descents, with reads landing both before
    and after compactions (the delta capacity is sized so the stream
    crosses the high-water mark mid-sequence)."""
    multi_device_host("""
        from repro.core import distributed as D
        from repro.core.engine import EngineConfig
        from repro.data.keysets import make_tree_data
        from repro.serving import BSTServer

        keys, values = make_tree_data(150, seed=3, spacing=3)
        rng = np.random.default_rng(5)

        def drive(srv, ref, rounds, n_writes, n_reads):
            compact_seen = 0
            for r in range(rounds):
                tickets = []
                wk = rng.integers(1, 600, n_writes).astype(np.int32)
                wv = rng.integers(0, 10**6, n_writes).astype(np.int32)
                tickets.append((srv.submit_write(wk, wv), ref.submit_write(wk, wv)))
                dk = rng.integers(1, 600, max(1, n_writes // 3)).astype(np.int32)
                tickets.append((srv.submit_delete(dk), ref.submit_delete(dk)))
                q = rng.integers(1, 660, n_reads).astype(np.int32)
                span = rng.integers(0, 40, n_reads).astype(np.int32)
                for op in ("lookup", "predecessor", "successor"):
                    tickets.append((srv.submit(q, op=op), ref.submit(q, op=op)))
                for op in ("range_count", "range_scan"):
                    tickets.append((
                        srv.submit_range(q, q + span, op=op),
                        ref.submit_range(q, q + span, op=op),
                    ))
                out_s, out_r = srv.drain(), ref.drain()
                for ts, tr in tickets:
                    for cs, cr in zip(out_s[ts], out_r[tr]):
                        assert np.array_equal(np.asarray(cs), np.asarray(cr)), (
                            r, ts)
                if compact_seen == 0 and srv.stats.compactions > 0:
                    compact_seen = r + 1  # later rounds probe post-compaction
            assert srv.stats.compactions == ref.stats.compactions
            return compact_seen

        for strategy, use_kernel, rounds, n_reads in (
            ("hrz", False, 4, 96), ("dup", False, 4, 96), ("hyb", False, 4, 96),
            ("hrz", True, 2, 48), ("dup", True, 2, 48), ("hyb", True, 2, 48),
        ):
            cfg = EngineConfig(
                strategy=strategy,
                n_trees=1 if strategy == "hrz" else 4,
                use_kernel=use_kernel,
                delta_capacity=48,
                delta_high_water=40,
            )
            mesh = D.make_serving_mesh(strategy)
            srv = BSTServer(keys, values, cfg, chunk_size=32, scan_k=4, mesh=mesh)
            ref = BSTServer(keys, values, cfg, chunk_size=32, scan_k=4)
            compact_round = drive(srv, ref, rounds, n_writes=24, n_reads=n_reads)
            # pre- AND post-compaction reads must both have been compared
            assert srv.stats.compactions > 0, (strategy, use_kernel)
            assert 0 < compact_round <= rounds, (strategy, use_kernel)
            print("ok", strategy, "kernel" if use_kernel else "ref",
                  "compactions", srv.stats.compactions)
        print("ALL OK")
    """, timeout=2400)


def test_sharded_server_soak_mixed_accounting(multi_device_host):
    """≥ 500-op mixed read/write soak through the sharded server, per mix.

    Beyond correctness (lookups cross-checked against a dict oracle), the
    per-op ``OpStats`` lane accounting and throughput figures must tie out
    EXACTLY against the submitted op counts: one lane per point/write/
    delete key, two per range request, busy seconds partitioning into the
    per-op attributions, and keys/lanes-per-sec being served/lanes over
    busy time."""
    multi_device_host("""
        from repro.core import distributed as D
        from repro.core.engine import EngineConfig
        from repro.data.keysets import make_tree_data
        from repro.serving import BSTServer

        keys, values = make_tree_data(150, seed=9, spacing=3)
        for mix, write_frac in (("90_10", 0.10), ("50_50", 0.50)):
            rng = np.random.default_rng(17 if mix == "90_10" else 23)
            cfg = EngineConfig(
                strategy="hyb", n_trees=4,
                delta_capacity=64, delta_high_water=24,
            )
            srv = BSTServer(
                keys, values, cfg, chunk_size=64, scan_k=4,
                mesh=D.make_serving_mesh("hyb"),
            )
            kv = dict(zip(keys.tolist(), values.tolist()))
            n_ops = 520
            counts = {}
            expected = {}  # ticket -> (op, key, kv-at-submit)
            kinds = ("write", "delete", "lookup", "predecessor",
                     "successor", "range_count", "range_scan")
            w = write_frac
            probs = [w * 0.7, w * 0.3] + [(1 - w) / 5] * 5
            choice = rng.choice(np.array(kinds), n_ops, p=probs)
            for i, op in enumerate(choice.tolist()):
                q = int(rng.integers(1, 500))
                counts[op] = counts.get(op, 0) + 1
                if op == "write":
                    v = int(rng.integers(0, 10**6))
                    t = srv.submit_write(q, v)
                    kv[q] = v
                elif op == "delete":
                    t = srv.submit_delete(q)
                    kv.pop(q, None)
                elif op in ("range_count", "range_scan"):
                    t = srv.submit_range(q, q + 30, op=op)
                else:
                    t = srv.submit(q, op=op)
                    if op == "lookup":
                        expected[t] = (q, dict(kv))
                if (i + 1) % 80 == 0 or i == n_ops - 1:
                    results = srv.drain()
                    for t, (q, snap) in expected.items():
                        val, found = results[t]
                        assert bool(found[0]) == (q in snap), (mix, q)
                        if q in snap:
                            assert int(val[0]) == snap[q], (mix, q)
                    expected = {}
            s = srv.stats
            assert s.requests == n_ops and s.submitted == n_ops
            assert s.served == n_ops and srv.pending() == 0
            # --- per-op lane accounting ties out against the op counts:
            # singleton requests -> one lane per point/write/delete op, two
            # per range request (the lo||hi concatenated descent)
            for op, n in counts.items():
                st = s.per_op[op]
                assert st.served == n, (mix, op)
                lanes = 2 * n if op.startswith("range") else n
                assert st.lanes == lanes, (mix, op, st.lanes, lanes)
                assert st.chunks > 0 and st.busy_s > 0, (mix, op)
                # the throughput figures ARE served/lanes over busy time
                assert abs(st.keys_per_sec * st.busy_s - st.served) < 1e-6
                assert abs(st.lanes_per_sec * st.busy_s - st.lanes) < 1e-6
            assert s.lanes == sum(
                (2 * n if op.startswith("range") else n)
                for op, n in counts.items()
            )
            assert sum(st.lanes for st in s.per_op.values()) == s.lanes
            assert abs(s.keys_per_sec * s.busy_s - s.served) < 1e-6
            assert abs(s.lanes_per_sec * s.busy_s - s.lanes) < 1e-6
            # read busy attributions partition the read-span walls; write
            # spans attribute their whole wall across their requests
            read_busy = sum(
                st.busy_s for op, st in s.per_op.items()
                if op not in ("write", "delete")
            )
            write_busy = sum(
                st.busy_s for op, st in s.per_op.items()
                if op in ("write", "delete")
            )
            assert abs(read_busy + write_busy - s.busy_s) < 1e-6, mix
            assert s.updates == counts["write"] + counts["delete"]
            assert s.compactions > 0, mix  # the soak crosses the high-water
            print("ok", mix, "ops", n_ops, "compactions", s.compactions)
        print("ALL OK")
    """, timeout=2400)
