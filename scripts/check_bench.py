"""Gatekeeper for the benchmark artifact (BENCH_*.json).

Four checks, all against the SAME run's file -- no cross-run baselines to
go stale:

  1. schema: the file matches ``bench-rows/v1`` (re-validated here on the
     consumer side; ``benchmarks/run.py`` already checks it at write time);
  2. coverage: the engine suite must emit ordered-op rows (DESIGN.md §6),
     mixed read/write serving rows (§7), hyb kernel-vs-driver pairs (§8)
     and the sharded serving family (§9: ``serve/sharded_*`` rows for all
     of hrz / dup / hyb plus a sharded mixed row) -- a silently dropped
     row family is a failure, not a skip;
  3. regression gate: for every ``pair=<name>`` tag, the in-kernel hyb
     path (``hyb_kernel``) must not be slower than the retired
     driver-level composition (``hyb_driver``) recorded in the same run
     (beyond ``JITTER_TOLERANCE`` of timing noise).  The driver path was
     deleted from the engine precisely because the kernel path beat it;
     this gate keeps that true;
  4. sharded-vs-single-chip gate (same run, ``spair=<strategy>`` tags):
     every sharded serving mode must beat the single-chip server on ITS
     scaling axis (DESIGN.md §9).  dup -- replicate-and-split, the
     throughput play -- must serve at least as many keys/sec (within
     ``SHARD_JITTER_TOLERANCE``; batches are >= 4k rows by schema).
     hrz / hyb -- subtree sharding, the capacity play -- must store
     STRICTLY fewer nodes per device (``mem_nodes_dev``, MEASURED from
     the runner's real shard layout, so a silently replicated operand
     trips it), an exact number a host-simulated mesh can gate without
     CPU timing noise.

Usage: ``python scripts/check_bench.py BENCH_5.json``
"""

from __future__ import annotations

import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import report  # noqa: E402  (shared exit-code helper)

# The per-pair gate trips only when the kernel path is slower than the
# driver by more than runner jitter: both timings are interpret-mode
# medians on a shared CI box, and the queue-pair margins are 2.6-4.1x
# (BENCH_4.json), so 10% headroom cannot hide a genuine regression -- it
# only keeps a noise flip from hard-failing the pipeline.
JITTER_TOLERANCE = 1.10
# The direct-mapped pairs carry ~300-450x headroom because their baseline
# is the retired driver's deliberately pathological O(B*n*capacity)
# dispatch -- against that, the pair gate alone is vacuous.  The sibling
# bound closes the hole: each direct kernel row must stay within this
# factor of its queue sibling (HybN vs HybNq, same run; today they are
# within ~2x of each other), so a direct-path blow-up (e.g. the
# shifted-compare clash loop regressing to quadratic) fails CI even
# though the retired baseline never would catch it.
SIBLING_TOLERANCE = 25.0
# The dup sharded-vs-single throughput gate: both sides are interleaved
# A/B medians over the same stream in the same subprocess, so systematic
# regressions (a scheduler that stopped overlapping, a sharded program
# recompiling per chunk) blow far past this, while CPU-runner noise on a
# host-simulated mesh stays inside it.
SHARD_JITTER_TOLERANCE = 1.25
# The sharded rows must demonstrate serving-scale batches (acceptance:
# the comparison holds on >= 4k-row chunks).
SHARD_MIN_BATCH = 4096


def derived_dict(row) -> dict:
    return dict(
        part.split("=", 1) for part in filter(None, row["derived"].split(";"))
    )


def main(path: str) -> None:
    sys.path.insert(0, os.path.join(REPO_ROOT, "benchmarks"))
    from run import SCHEMA, validate_rows  # the single schema definition

    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise SystemExit(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    rows = doc.get("rows", [])
    validate_rows(rows)
    # Every check below appends to ONE inventory and finishes through the
    # shared report.gate helper (same contract as scripts/check_static.py):
    # a run surfaces every failure, never just the first.
    failures = []

    # --- coverage: the row families CI watches must actually exist
    ordered = [
        r for r in rows
        if any(f"/{op}" in r["name"]
               for op in ("predecessor", "range_count", "range_scan"))
    ]
    if not ordered:
        failures.append("no ordered-op benchmark rows emitted")
    mixed = {m for r in rows for m in ("90_10", "50_50") if m in r["name"]}
    if mixed != {"90_10", "50_50"}:
        failures.append(f"missing mixed read/write rows (got {sorted(mixed)})")
    for r in rows:
        if "/mixed_" in r["name"] and "compactions" not in derived_dict(r):
            failures.append(f"mixed row without compactions: {r['name']}")

    # --- hyb kernel-vs-driver regression gate (same-run baseline)
    pairs: dict = {}
    for r in rows:
        d = derived_dict(r)
        if "pair" in d:
            kind = r["name"].rsplit("/", 1)[-1]
            pairs.setdefault(d["pair"], {})[kind] = r["us_per_call"]
    complete = {
        p: v for p, v in pairs.items() if {"hyb_kernel", "hyb_driver"} <= set(v)
    }
    if not complete:
        failures.append("no hyb kernel-vs-driver pairs in the artifact")
    for name, v in sorted(complete.items()):
        speedup = v["hyb_driver"] / v["hyb_kernel"]
        print(f"hyb gate {name}: kernel {v['hyb_kernel']:.0f}us vs "
              f"driver {v['hyb_driver']:.0f}us ({speedup:.2f}x)")
        if v["hyb_kernel"] > v["hyb_driver"] * JITTER_TOLERANCE:
            failures.append(
                f"hyb kernel path slower than the retired driver: {name}"
            )
    for name, v in sorted(complete.items()):
        sibling = name + "q"  # HybN's queue twin, timed in the same run
        if sibling in complete:
            bound = complete[sibling]["hyb_kernel"] * SIBLING_TOLERANCE
            if v["hyb_kernel"] > bound:
                failures.append(
                    f"hyb kernel path past its queue sibling's bound: "
                    f"{name} (vs {sibling})"
                )

    # --- sharded serving family (DESIGN.md §9): coverage + same-run gate
    spairs: dict = {}
    for r in rows:
        d = derived_dict(r)
        if "spair" in d:
            spairs.setdefault(d["spair"], {})[d.get("mode", "?")] = (
                r["us_per_call"], d
            )
    missing = {"hrz", "dup", "hyb"} - set(spairs)
    if missing:
        failures.append(f"missing sharded serving rows for {sorted(missing)}")
    if not any("sharded_mixed" in r["name"] for r in rows):
        failures.append("no sharded mixed read/write row emitted")
    for strategy, modes in sorted(spairs.items()):
        if {"sharded", "single"} - set(modes):
            failures.append(
                f"sharded pair {strategy!r} incomplete (got {sorted(modes)})"
            )
            continue
        s_us, s_d = modes["sharded"]
        c_us, c_d = modes["single"]
        for d in (s_d, c_d):
            if int(d.get("batch", 0)) < SHARD_MIN_BATCH:
                failures.append(
                    f"sharded pair {strategy!r} batch {d.get('batch')} below "
                    f"the {SHARD_MIN_BATCH}-row serving floor"
                )
        if strategy == "dup":
            # The throughput play: same stream, interleaved medians.
            print(f"shard gate dup: sharded {s_us:.0f}us vs single "
                  f"{c_us:.0f}us ({c_us / s_us:.2f}x)")
            if s_us > c_us * SHARD_JITTER_TOLERANCE:
                failures.append(
                    "sharded serving lost to single-chip: dup (throughput)"
                )
        else:
            # The capacity play: strictly fewer stored nodes per device.
            s_mem = int(s_d["mem_nodes_dev"])
            c_mem = int(c_d["mem_nodes_dev"])
            print(f"shard gate {strategy}: {s_mem} nodes/device sharded vs "
                  f"{c_mem} single ({c_mem / max(s_mem, 1):.2f}x)")
            if s_mem >= c_mem:
                failures.append(
                    f"sharded serving lost to single-chip: {strategy} "
                    "(mem_nodes_dev)"
                )
    report.gate(
        failures,
        f"{path}: schema + coverage + hyb gate + sharded gate OK "
        f"({len(rows)} rows, {len(complete)} pairs, {len(spairs)} spairs)",
    )


if __name__ == "__main__":
    main(
        sys.argv[1]
        if len(sys.argv) > 1
        else os.path.join(REPO_ROOT, "BENCH_5.json")
    )
