"""Smoke-execute README.md's code-block commands so the docs cannot drift.

CI runs this after the tier-1 step: every ``PYTHONPATH=src python ...`` line
inside a fenced ```bash block is executed from the repo root and must exit
0.  The pytest line is skipped (tier-1 already ran it as its own job step);
everything else -- quickstart, benchmarks, serving -- runs for real, so a
README command that stops working fails the job.

    python scripts/readme_smoke.py [README.md]
"""

from __future__ import annotations

import re
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
TIMEOUT_S = 1200


def bash_blocks(text: str) -> list[str]:
    return re.findall(r"```bash\n(.*?)```", text, flags=re.DOTALL)


def runnable_commands(readme: Path) -> list[str]:
    cmds = []
    for block in bash_blocks(readme.read_text()):
        for line in block.splitlines():
            line = line.strip()
            if not line.startswith("PYTHONPATH=src python"):
                continue  # pip installs etc. are environment setup, not ours
            if "pytest" in line or "benchmarks.run" in line:
                continue  # tier-1 and the benchmark suite run as their own
                # CI steps (same commands); re-running them here would only
                # double the job's wall clock
            cmds.append(line)
    return cmds


def main() -> int:
    readme = Path(sys.argv[1]) if len(sys.argv) > 1 else ROOT / "README.md"
    cmds = runnable_commands(readme)
    if not cmds:
        print(f"ERROR: no runnable PYTHONPATH=src commands found in {readme}")
        return 1
    if not any("examples/quickstart.py" in c for c in cmds):
        print("ERROR: README.md no longer shows the quickstart command")
        return 1
    failures = 0
    for cmd in cmds:
        print(f"--- {cmd}")
        t0 = time.time()
        proc = subprocess.run(cmd, shell=True, cwd=ROOT, timeout=TIMEOUT_S)
        status = "ok" if proc.returncode == 0 else f"FAILED rc={proc.returncode}"
        print(f"--- {status} ({time.time() - t0:.1f}s)")
        failures += proc.returncode != 0
    if failures:
        print(f"{failures}/{len(cmds)} README command(s) failed")
        return 1
    print(f"all {len(cmds)} README command(s) ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
