"""CI wrapper for the static checks: ``python scripts/check_static.py``.

Thin shim over ``python -m repro.analysis`` that pins the repo root and
``src`` path so the job runs from any cwd.  All behavior (passes, flags,
exit-code contract) lives in ``repro.analysis.__main__``; the report
helper it finishes through is the same one ``check_bench.py`` uses.
"""

from __future__ import annotations

import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main(["--repo-root", REPO_ROOT, *sys.argv[1:]]))
