import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: pjit must
partition every step over the production meshes, the compiled module must
report its per-device memory, and the HLO must contain a sane collective
schedule.  Results (cost_analysis, memory_analysis, collective bytes parsed
from the partitioned HLO) are written as JSON for EXPERIMENTS.md §Dry-run
and the roofline analysis (benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, canonical, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.config import (  # noqa: E402
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_runnable,
    input_specs,
)

RESULT_DIR = os.environ.get(
    "DRYRUN_OUT",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"),
)

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:condition=%?([\w\.\-]+))|(?:body=%?([\w\.\-]+))|"
    r"(?:calls=%?([\w\.\-]+))|(?:to_apply=%?([\w\.\-]+))"
)


def _split_computations(hlo_text: str):
    """(computation name -> body lines, entry name).

    A computation header is any column-0 line ending in '{' (params may
    contain nested parens/tuples, so we key on the trailing brace only).
    """
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line[:1] not in (" ", ""):
            if line.rstrip().endswith("{") and not line.startswith("HloModule"):
                m = _COMP_NAME_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if line.startswith("ENTRY"):
                        entry = cur
                    continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list) -> int:
    """Heuristic: the loop bound is the max s32 constant in the while cond."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Collective op bytes in the partitioned HLO, scaled by loop trip counts.

    XLA's cost_analysis counts while-loop bodies ONCE (verified in this
    container); collectives inside the layer/chunk scans would be similarly
    under-counted from a flat text scan.  We therefore walk the call graph
    from ENTRY, multiplying by each enclosing while-loop's trip count
    (parsed from the loop condition's bound constant).
    """
    comps, entry = _split_computations(hlo_text)

    # per-computation: own collectives and calls (with loop multiplier)
    def line_op(s: str):
        m = re.search(
            r"=\s*((?:\([^)]*\))|(?:[a-z0-9\[\],{}\s]*?))\s*([a-z\-]+)\(", s
        )
        if not m:
            return None
        op = m.group(2)
        for suffix in ("-start", "-done"):
            if op.endswith(suffix):
                op = op[: -len(suffix)]
        return (op, m.group(1)) if op in _COLLECTIVES else None

    out: Dict[str, Any] = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    seen_done = set()

    def walk(name: str, mult: int, stack):
        if name not in comps or name in stack:
            return
        stack = stack + (name,)
        for ln in comps[name]:
            s = ln.strip()
            op = line_op(s)
            if op is not None and "-done" not in s.split("(")[0]:
                kind, shape_text = op
                out[kind]["count"] += mult
                out[kind]["bytes"] += _shape_bytes(shape_text) * mult
            body = cond = None
            called = []
            for m in _CALL_RE.finditer(s):
                c, b, call, apply_ = m.groups()
                if c:
                    cond = c
                if b:
                    body = b
                if call:
                    called.append(call)
                if apply_:
                    called.append(apply_)
            if body is not None:
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                walk(body, mult * trips, stack)
                if cond:
                    walk(cond, mult * trips, stack)
            for c in called:
                walk(c, mult, stack)

    if entry is not None:
        walk(entry, 1, ())
    else:  # fallback: flat scan of every computation
        for name in list(comps):
            walk(name, 1, ())
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values() if isinstance(v, dict))
    return out


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    out["peak_per_device_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def build_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh, variant=None):
    """Lower the step function for one cell with production shardings.

    ``variant`` (perf iterations): dict of ModelConfig field overrides, plus
    the special key ``seq_shard_cache`` for sequence-parallel decode caches.
    """
    import dataclasses as _dc

    from repro.sharding import specs as S
    from repro.training.train_loop import TrainConfig, TrainState, make_train_step
    from repro.optim.optimizer import AdamWState

    variant = dict(variant or {})
    seq_shard = variant.pop("seq_shard_cache", None)  # None = auto rule
    microbatches = variant.pop("microbatches", 1)
    if variant:
        cfg = _dc.replace(cfg, **variant)

    ins = input_specs(cfg, shape)
    B = shape.global_batch

    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=microbatches)
        step = make_train_step(cfg, tcfg, mesh=mesh, mode="pjit", donate=True)
        # abstract state: ShapeDtypeStructs in the exact pytree layout
        params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        state = TrainState(
            params=params,
            opt=AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                master=jax.tree.map(f32, params),
                mu=jax.tree.map(f32, params),
                nu=jax.tree.map(f32, params),
            ),
            error_feedback=(),
        )
        args = (state, ins["tokens"], ins["labels"])
        if cfg.frontend is not None:
            args = args + (ins["frontend_embeds"],)
        return step.lower(*args)

    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))
    if shape.kind == "prefill":
        from repro.serving.serve_loop import make_prefill_fn

        fn = make_prefill_fn(cfg, mesh=mesh, batch=B, max_len=shape.seq_len)
        args = (params, ins["tokens"])
        if cfg.frontend is not None:
            args = args + (ins["frontend_embeds"],)
        return fn.lower(*args)

    # decode
    from repro.serving.serve_loop import make_serve_step

    cache = M.make_decode_state(cfg, B, shape.seq_len, as_specs=True)
    step = make_serve_step(cfg, mesh=mesh, batch=B, seq_shard=seq_shard)
    return step.lower(params, ins["tokens"], cache)


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    save: bool = True,
    variant=None,
    tag: str = "",
):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    runnable, reason = cell_is_runnable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": mesh_kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "kind": shape.kind,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    }
    if variant:
        rec["variant"] = {k: str(v) for k, v in variant.items()}
        rec["tag"] = tag
    if not runnable:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return _finish(rec, save, tag)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with mesh:
            lowered = build_lowered(cfg, shape, mesh, variant=variant)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            rec["lower_s"] = round(t1 - t0, 2)
            rec["compile_s"] = round(t2 - t1, 2)
            rec["memory_analysis"] = _mem_analysis(compiled)
            rec["cost_analysis"] = _cost_analysis(compiled)
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
            rec["status"] = "ok"
            print(compiled.memory_analysis())
            ca = rec["cost_analysis"]
            print(
                f"[{cfg.name} x {shape_name} x {mesh_kind}] "
                f"flops={ca.get('flops', 0):.3e} "
                f"bytes={ca.get('bytes accessed', 0):.3e} "
                f"collective_bytes={rec['collectives']['total_bytes']:.3e} "
                f"compile={rec['compile_s']}s"
            )
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{cfg.name} x {shape_name} x {mesh_kind}] FAILED: {rec['error']}")
    return _finish(rec, save, tag)


def _finish(rec, save, tag: str = ""):
    if save:
        os.makedirs(RESULT_DIR, exist_ok=True)
        slug = f"{canonical(rec['arch'])}_{rec['shape']}_{rec['mesh']}"
        if tag:
            slug += f"__{tag}"
        with open(os.path.join(RESULT_DIR, slug + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (see configs/)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose result JSON already says ok/skipped")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or args.arch is None) else [canonical(args.arch)]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    failures = 0
    for mk in meshes:
        for a in archs:
            for s in shapes:
                if args.skip_existing:
                    p = os.path.join(RESULT_DIR, f"{canonical(a)}_{s}_{mk}.json")
                    if os.path.exists(p):
                        try:
                            if json.load(open(p)).get("status") in ("ok", "skipped"):
                                continue
                        except Exception:
                            pass
                rec = run_cell(a, s, mk)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
