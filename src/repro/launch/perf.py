import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb runner: re-lower the three chosen cells with variants.

Each iteration = hypothesis -> change -> re-lower -> re-analyse, written to
experiments/dryrun/<slug>__<tag>.json; the before rows are the baseline
files without a tag.  EXPERIMENTS.md §Perf narrates the numbers.

  PYTHONPATH=src python -m repro.launch.perf --iter 1   (or 2, 3, all)
"""

import argparse  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

# (arch, shape, variant, tag, hypothesis) — the three §Perf cells + extras
ITERATIONS = {
    "1": (
        "mixtral_8x7b",
        "train_4k",
        {"moe_groups": 32},
        "moegroups32",
        "grouped dispatch makes the token prefix-sum device-local; "
        "collective bytes should drop ~100x to grad-allreduce + TP levels",
    ),
    "1b": (
        "mixtral_8x22b",
        "train_4k",
        {"moe_groups": 32},
        "moegroups32",
        "same as iter 1 on the 141B config",
    ),
    "2": (
        "granite_3_8b",
        "decode_32k",
        {"seq_shard_cache": True},
        "seqshard",
        "sequence-sharding the KV cache over the model axis divides cache "
        "residency by 16 and replaces the gather with tiny softmax-stat "
        "reductions",
    ),
    "3": (
        "hymba_1p5b",
        "train_4k",
        {},  # the change is the q-block-outer attention (code-level)
        "qblockattn",
        "q-block-outer attention with rematerialized inner scans saves only "
        "the attention output per block instead of nblk x (B,S,KV,G,hd) "
        "accumulator carries; peak memory should drop several-fold",
    ),
    "2b": (
        "qwen3_1p7b",
        "decode_32k",
        {"seq_shard_cache": True},
        "seqshard",
        "iter 2's fix generalizes to every kv_heads<model arch",
    ),
    "1c": (
        "mixtral_8x7b",
        "prefill_32k",
        {"moe_groups": 32},
        "moegroups32",
        "grouped dispatch fixes prefill's collective term too",
    ),
    # --- revised after iter 1 was REFUTED: the TB-scale all-reduce came from
    # the scatter-add combine, not the prefix-sum dispatch.
    "1r": (
        "mixtral_8x7b",
        "train_4k",
        {"moe_groups": 32},
        "gathercombine",
        "gather-based combine (no scatter over the token dim) + grouped "
        "dispatch: GSPMD keeps all MoE traffic group-local; expect collective "
        "bytes to fall from ~9.8 TB to grad-allreduce + TP scale (<0.5 TB)",
    ),
    "1rb": (
        "mixtral_8x22b",
        "train_4k",
        {"moe_groups": 32},
        "gathercombine",
        "same on 141B",
    ),
    "1rc": (
        "mixtral_8x7b",
        "prefill_32k",
        {"moe_groups": 32},
        "gathercombine",
        "same fix on the prefill cell",
    ),
    "3b": (
        "hymba_1p5b",
        "train_4k",
        {"microbatches": 4},
        "qblockattn_mb4",
        "grad accumulation over 4 microbatches divides live activations by 4 "
        "on top of iter 3: 41 GiB -> ~16 GiB/device",
    ),
    # --- iter 4: the roofline table shows TP activation collectives dominate
    # every dense train cell (granite: 15.1 s collective vs 1.5 s compute).
    # At global_batch 256 == chips and <= 8B params, pure DP eliminates them.
    "4": (
        "granite_3_8b",
        "train_4k",
        {"sharding_strategy": "dp_only"},
        "dponly",
        "replicated params + batch over all 256 chips: activation collectives "
        "-> 0; remaining traffic = one grad all-reduce (~8B x 4B x 2 wire / "
        "256 = manageable); expect collective term 15.1 s -> ~1.3 s, cell "
        "flips compute-bound",
    ),
    "4b": (
        "qwen3_1p7b",
        "train_4k",
        {"sharding_strategy": "dp_only"},
        "dponly",
        "same for qwen3 (5.2 s collective vs 0.33 s compute at baseline)",
    ),
    "4c": (
        "hymba_1p5b",
        "train_4k",
        {"sharding_strategy": "dp_only", "microbatches": 4},
        "dponly_mb4",
        "combine iter 3b with pure DP for the hybrid arch",
    ),
    # --- iter 1p: gather-combine alone was NOT enough (GSPMD still chose to
    # replicate the (E,C,D) buffers).  Pin the group axis to the DP mesh axes
    # with explicit with_sharding_constraint.
    "1p": (
        "mixtral_8x7b",
        "train_4k",
        {"moe_groups": 32},
        "pinned",
        "explicit sharding constraints pin the dispatch group dim to "
        "(pod,data): every gather/einsum/combine is group-local; expect "
        "collective bytes ~9.9 TB -> < 1 TB",
    ),
    "1pb": (
        "mixtral_8x22b",
        "train_4k",
        {"moe_groups": 32},
        "pinned",
        "same on 141B",
    ),
    "1pc": (
        "mixtral_8x7b",
        "prefill_32k",
        {"moe_groups": 32},
        "pinned",
        "same fix on the prefill cell",
    ),
    # --- iter 5: iter 1p leaves 41 GB/device of param+opt state on the
    # mixtral cell (> 16 GB HBM).  ZeRO-1 shards master/mu/nu over the data
    # axis along each leaf's leading (stacked-layer) dim.
    "5": (
        "mixtral_8x7b",
        "train_4k",
        {"moe_groups": 32, "zero1": True},
        "pinned_zero1",
        "ZeRO-1 opt-state sharding: argument bytes 41 GB -> ~6 GB/device; "
        "grads reduce-scatter instead of all-reduce (less wire too)",
    ),
    "5b": (
        "qwen3_1p7b",
        "train_4k",
        {"sharding_strategy": "dp_only", "zero1": True},
        "dponly_zero1",
        "dp_only replicates 24 GB of opt state on qwen3; ZeRO-1 shards it "
        "over data along stacked-layer dims",
    ),
    "5c": (
        "mixtral_8x22b",
        "train_4k",
        {"moe_groups": 32, "zero1": True},
        "pinned_zero1",
        "the 141B config only becomes HBM-feasible with both fixes",
    ),
    # --- stacking the adopted fixes per cell
    "4d": (
        "granite_3_8b",
        "train_4k",
        {"sharding_strategy": "dp_only", "zero1": True, "microbatches": 2},
        "dponly_zero1_mb2",
        "iter 4 won 10x on collectives but replicated 109 GB of state; "
        "ZeRO-1 (generalized to the first divisible dim) shards it back and "
        "mb=2 halves live activations",
    ),
    "4e": (
        "hymba_1p5b",
        "train_4k",
        {"sharding_strategy": "dp_only", "zero1": True, "microbatches": 4},
        "dponly_mb4_zero1",
        "hymba final stack: dp_only + mb4 + ZeRO-1",
    ),
    "5d": (
        "mixtral_8x7b",
        "train_4k",
        {"moe_groups": 32, "zero1": True, "microbatches": 8},
        "pinned_zero1_mb8",
        "mixtral final stack: 60 GB of temp is microbatchable activations; "
        "mb=8 should land the cell near the 16 GB HBM budget",
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iter", default="all")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    keys = list(ITERATIONS) if args.iter == "all" else [args.iter]
    for k in keys:
        arch, shape, variant, tag, hyp = ITERATIONS[k]
        print(f"=== iter {k}: {arch} x {shape} [{tag}]\n    hypothesis: {hyp}")
        run_cell(arch, shape, args.mesh, variant=variant, tag=tag)


if __name__ == "__main__":
    main()
