import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run the PAPER'S OWN workload on the production mesh: the distributed
hybrid BST engine (vertical subtrees over `model`, duplication over
`data`/`pod`) serving a key chunk per device.

This is the roofline for the reproduced system itself, complementing the
LM-architecture table: a 2^21-node tree (like the paper's 2^20-node
discussion scaled to fill VMEM-era HBM), 16 M keys per global chunk.

The lowered pipeline is the SAME phase chain the engines run (core/plans:
route -> dispatch -> all_to_all -> forest descent -> combine); we lower the
membership variant, which bounds the ordered query ops too -- every op is
one descent of identical traffic shape, plus a fixed 5 extra int32 lanes of
OrderedResult payload on the return collective (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.dryrun_bst [--mesh single|multi]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import plans as plans_lib  # noqa: E402
from repro.launch import dryrun as DR  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding.compat import shard_map  # noqa: E402

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def build_lookup_lowered(mesh, tree_nodes: int, chunk_per_device: int, capacity_frac: float):
    """Lower the shard_map hybrid lookup with abstract tree/query operands.

    The pipeline is the SAME phase chain as engine/distributed (core/plans);
    only the operands are abstract and the collectives explicit.
    """
    M = mesh.shape["model"]
    split = int(math.log2(M))
    height = int(math.log2(tree_nodes + 1)) - 1
    sub_h = height - split
    sub_n = (1 << (sub_h + 1)) - 1
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    B_local = chunk_per_device
    B_global = B_local * n_dev
    cap = max(1, int(B_local * capacity_frac))

    reg_n = (1 << max(split, 1)) - 1
    reg_keys = jnp.arange(1, reg_n + 1, dtype=jnp.int32)  # placeholder values
    reg_vals = jnp.arange(1, reg_n + 1, dtype=jnp.int32)

    def _local(queries, sub_k, sub_v):
        dest, val, found = plans_lib.route_phase(reg_keys, reg_vals, queries, split)
        dplan = plans_lib.dispatch_phase("queue", dest, M, cap, active=~found)
        send_q, send_live = plans_lib.gather_phase(queries, dplan)
        recv_q = jax.lax.all_to_all(send_q, "model", 0, 0)
        recv_live = jax.lax.all_to_all(send_live.astype(jnp.int32), "model", 0, 0) != 0
        vals, fnd = plans_lib.descend_phase(
            sub_k, sub_v, sub_h, recv_q.reshape(1, -1), recv_live.reshape(1, -1)
        )
        back_v = jax.lax.all_to_all(vals[0].reshape(M, cap), "model", 0, 0)
        back_f = jax.lax.all_to_all(
            fnd[0].astype(jnp.int32).reshape(M, cap), "model", 0, 0
        )
        got_v, got_f = plans_lib.combine_phase(back_v, back_f != 0, dplan, B_local)
        return jnp.where(found, val, got_v), found | got_f

    axes = tuple(mesh.axis_names)
    from jax.sharding import PartitionSpec as P

    fn = jax.jit(
        shard_map(
            _local,
            mesh=mesh,
            in_specs=(P(axes), P("model", None), P("model", None)),
            out_specs=(P(axes), P(axes)),
            check=False,
        )
    )
    q = jax.ShapeDtypeStruct((B_global,), jnp.int32)
    sub_k = jax.ShapeDtypeStruct((M, sub_n), jnp.int32)
    sub_v = jax.ShapeDtypeStruct((M, sub_n), jnp.int32)
    return fn.lower(q, sub_k, sub_v), B_global, height


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tree-nodes", type=int, default=(1 << 21) - 1)
    ap.add_argument("--chunk-per-device", type=int, default=65536)
    ap.add_argument("--capacity-frac", type=float, default=1.0)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    t0 = time.time()
    with mesh:
        lowered, B_global, height = build_lookup_lowered(
            mesh, args.tree_nodes, args.chunk_per_device, args.capacity_frac
        )
        compiled = lowered.compile()
    dt = time.time() - t0
    print(compiled.memory_analysis())
    cb = DR.collective_bytes(compiled.as_text())
    # analytic terms per device: descent = height compares over chunk lanes
    flops = args.chunk_per_device * (height + 1) * 4  # cmp+select per level
    hbm = args.chunk_per_device * (height + 1) * 8  # gather key+value
    t_comp = flops / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = cb["total_bytes"] * 2 / ICI_BW  # a2a there+back dominated
    rec = {
        "mesh": args.mesh,
        "tree_nodes": args.tree_nodes,
        "global_chunk": B_global,
        "keys_per_device": args.chunk_per_device,
        "capacity_frac": args.capacity_frac,
        "collectives": cb,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": max(
            [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)],
            key=lambda kv: kv[1],
        )[0],
        "keys_per_sec_bound": B_global / max(t_comp, t_mem, t_coll),
        "compile_s": round(dt, 2),
    }
    out = os.path.join(DR.RESULT_DIR, f"bst_engine_{args.mesh}.json")
    os.makedirs(DR.RESULT_DIR, exist_ok=True)
    json.dump(rec, open(out, "w"), indent=1)
    print(json.dumps({k: v for k, v in rec.items() if k != "collectives"}, indent=1))
    print("collective bytes/device:", cb["total_bytes"])


if __name__ == "__main__":
    main()
