"""Production meshes.

Axis roles (DESIGN.md §5): pod/data = data parallelism (and BST tree
duplication), model = tensor/expert/vertical-subtree parallelism.

Defined as FUNCTIONS so importing this module never touches jax device
state -- the dry-run must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return make_mesh((n // model, model), ("data", "model"))
