"""Training driver: fault-tolerant loop around make_train_step.

Single-host today, but structured the way a 1000-node job needs:
  * pure-function step over explicit TrainState;
  * checkpoint manager with atomic step-tagged saves + retention + async;
  * stateless-resumable data pipeline (batch = f(seed, step));
  * straggler/failure policy: per-step deadline -> abort-and-restart from
    the last checkpoint (on a pod this is where slice re-election and
    jax.distributed re-init would hook in; the state mechanics already
    support restoring onto a smaller mesh via checkpoint/elastic.py);
  * optional gradient compression (see training/train_loop.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 100 --smoke --ckpt-dir /tmp/ckpt [--resume]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import canonical, get_config, smoke_config
from repro.data.pipeline import TokenPipeline
from repro.training.train_loop import (
    TrainConfig,
    init_train_state,
    make_train_step,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default=None, choices=[None, "bf16", "int8"])
    ap.add_argument("--step-deadline-s", type=float, default=None,
                    help="straggler mitigation: abort if a step exceeds this")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        peak_lr=args.lr,
        warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps,
        microbatches=args.microbatches,
        compression=args.compression,
    )
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch)
    step_fn = make_train_step(cfg, tcfg)

    mgr = (
        CheckpointManager(args.ckpt_dir, keep=3, save_async=True)
        if args.ckpt_dir
        else None
    )
    state = init_train_state(cfg, tcfg, jax.random.key(0))
    step0 = 0
    if mgr is not None and args.resume:
        try:
            state, step0, extra = mgr.restore(state)
            step0 += 1
            print(f"resumed from step {step0 - 1}")
        except FileNotFoundError:
            pass

    t_start = time.time()
    for s in range(step0, args.steps):
        t0 = time.time()
        tokens, labels = pipe.batch_at(s)
        state, metrics = step_fn(state, jnp.asarray(tokens), jnp.asarray(labels))
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        if args.step_deadline_s and dt > args.step_deadline_s and s > step0:
            # Straggler policy: a healthy fleet restarts this worker from the
            # last checkpoint rather than dragging the collective.
            print(f"step {s} exceeded deadline ({dt:.1f}s) -- aborting for restart")
            raise SystemExit(42)
        if mgr is not None and (s + 1) % args.ckpt_every == 0:
            mgr.save(s, state, extra={"pipeline_step": s})
        if s % 10 == 0 or s == args.steps - 1:
            tput = args.batch * args.seq / dt
            print(
                f"step {s:5d} loss={metrics['loss']:.4f} "
                f"gnorm={metrics['grad_norm']:.3f} lr={metrics['lr']:.2e} "
                f"{dt*1e3:.0f}ms {tput:.0f} tok/s"
            )
    if mgr is not None:
        mgr.save(args.steps - 1, state, extra={"pipeline_step": args.steps - 1})
        mgr.wait()
    print(f"done in {time.time() - t_start:.1f}s")
    return state


if __name__ == "__main__":
    main()
