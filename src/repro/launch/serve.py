"""Serving drivers: LM decoding, and the sharded BST store (DESIGN.md §9).

LM mode -- batched greedy decoding with prefill + KV cache:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16

BST mode -- the paper's accelerator served sharded over a host-simulated
mesh: ``BSTServer(mesh=...)`` routes fixed-shape chunks through the
strategy's shard_map-lowered plan behind the async double-buffered
scheduler, with live writes riding the replicated delta buffer:
  PYTHONPATH=src python -m repro.launch.serve --bst --bst-strategy hyb \
      --bst-devices 8 --requests 100000 --chunk 8192
"""

from __future__ import annotations

import argparse
import os
import sys
import time

# The forced host-device count must be set BEFORE jax initializes, and only
# the BST mode wants it (the LM path keeps the real devices), so the flag
# is argv-gated ahead of the jax import.
if "--bst" in sys.argv:
    _n = 8
    for _i, _a in enumerate(sys.argv):
        if _a == "--bst-devices" and _i + 1 < len(sys.argv):
            _n = int(sys.argv[_i + 1])
        elif _a.startswith("--bst-devices="):
            _n = int(_a.split("=", 1)[1])
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n}"
    )

import jax
import jax.numpy as jnp


def bst_main(args) -> None:
    """Serve a lookup + mixed write stream through the sharded BSTServer."""
    import numpy as np

    from repro.core.distributed import make_serving_mesh
    from repro.core.engine import EngineConfig
    from repro.data.keysets import make_tree_data
    from repro.serving import BSTServer

    strategy = args.bst_strategy
    mesh = make_serving_mesh(strategy)
    # The real device count can differ from --bst-devices when the
    # environment preset XLA_FLAGS (the argv gate never overrides it).
    n_devices = int(mesh.devices.size)
    n_trees = 1 if strategy == "hrz" else max(2, n_devices)
    cfg = EngineConfig(
        strategy=strategy,
        n_trees=n_trees,
        mapping="queue",
        delta_capacity=args.chunk // 2,
    )
    keys, values = make_tree_data((1 << 16) - 1, seed=0)
    srv = BSTServer(keys, values, cfg, chunk_size=args.chunk, mesh=mesh)
    srv.warmup()
    rng = np.random.default_rng(1)
    stream = rng.choice(keys, args.requests).astype(np.int32)

    t0 = time.time()
    srv.submit(stream)
    srv.drain()
    dt = time.time() - t0
    s = srv.stats
    print(
        f"sharded {strategy} x {n_devices} devices: "
        f"{args.requests} lookups in {dt:.2f}s "
        f"({s.keys_per_sec:.0f} keys/s busy, {s.found} found, "
        f"{s.chunks} chunks)"
    )

    # a mixed tail: writes ride the replicated delta buffer on-device
    wk = rng.integers(1, 2**20, args.chunk).astype(np.int32)
    srv.submit_write(wk, wk * 3)
    srv.submit(wk[: args.chunk // 2])
    srv.drain()
    v, f = srv.lookup(wk[:16])
    print(
        f"write path: {srv.stats.updates} updates absorbed on device, "
        f"{int(np.asarray(f).sum())}/16 fresh keys found, "
        f"{srv.stats.compactions} compaction(s)"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    # BST sharded serving mode (DESIGN.md §9)
    ap.add_argument("--bst", action="store_true", help="serve the BST store")
    ap.add_argument("--bst-strategy", default="hyb", choices=("hrz", "dup", "hyb"))
    ap.add_argument("--bst-devices", type=int, default=8)
    ap.add_argument("--requests", type=int, default=100_000)
    ap.add_argument("--chunk", type=int, default=8_192)
    args = ap.parse_args(argv)

    if args.bst:
        return bst_main(args)
    if args.arch is None:
        ap.error("--arch is required (or pass --bst for the BST store)")

    from repro.configs import get_config, smoke_config
    from repro.models import model as M
    from repro.serving.serve_loop import make_serve_step

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.key(0))
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        flen = S if cfg.family == "encdec" else cfg.frontend_len
        fe = (
            jax.random.normal(jax.random.key(2), (B, flen, cfg.d_model)) * 0.02
        ).astype(cfg.param_dtype)

    t0 = time.time()
    logits, state = M.prefill(cfg, params, prompts, fe, max_len=S + args.new_tokens)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    print(f"prefill: {B}x{S} in {t1-t0:.2f}s")

    step = make_serve_step(cfg)
    outs = [tok]
    for i in range(args.new_tokens - 1):
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    jax.block_until_ready(gen)
    dt = time.time() - t1
    print(
        f"decode: {args.new_tokens} tokens x {B} seqs in {dt:.2f}s "
        f"({B * args.new_tokens / dt:.1f} tok/s)"
    )
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
