"""Serving driver: batched greedy decoding with prefill + KV cache.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models import model as M
from repro.serving.serve_loop import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.key(0))
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        flen = S if cfg.family == "encdec" else cfg.frontend_len
        fe = (
            jax.random.normal(jax.random.key(2), (B, flen, cfg.d_model)) * 0.02
        ).astype(cfg.param_dtype)

    t0 = time.time()
    logits, state = M.prefill(cfg, params, prompts, fe, max_len=S + args.new_tokens)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    print(f"prefill: {B}x{S} in {t1-t0:.2f}s")

    step = make_serve_step(cfg)
    outs = [tok]
    for i in range(args.new_tokens - 1):
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    jax.block_until_ready(gen)
    dt = time.time() - t1
    print(
        f"decode: {args.new_tokens} tokens x {B} seqs in {dt:.2f}s "
        f"({B * args.new_tokens / dt:.1f} tok/s)"
    )
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
