"""Training step and loop: grad accumulation, compression, fault tolerance.

Distributed-optimization features (DESIGN.md §5):
  * microbatch gradient accumulation (lax.scan over microbatches) --
    pipelining lever for the memory roofline term;
  * optional gradient compression before the data-parallel all-reduce:
    "bf16" halves DP collective bytes, "int8" quarters them with per-leaf
    scale + error feedback (the residual is carried in the train state so
    compression noise does not bias the update);
  * straggler / failure handling lives in the driver (launch/train.py +
    checkpoint/elastic): the step itself is a pure function of
    (state, batch), which is what makes restart/reshard trivial.

Under pjit, gradients of data-parallel-replicated params are all-reduced by
XLA automatically; the compression hook wraps that reduction explicitly via
shard_map when enabled, so the collective really shrinks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1  # grad accumulation factor
    compression: Optional[str] = None  # None | "bf16" | "int8"
    b1: float = 0.9
    b2: float = 0.95


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamWState
    error_feedback: Any  # int8 compression residuals (or empty tuple)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> TrainState:
    params = M.init_params(cfg, key)
    ef = ()
    if tcfg.compression == "int8":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt.adamw_init(params), error_feedback=ef)


# ----------------------------------------------------------------- compression
def _compress_grads(grads, error_feedback, kind: Optional[str], axis_names):
    """Quantize -> psum over DP axes -> dequantize (+ error feedback)."""
    if kind is None:
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_names), grads), error_feedback
    if kind == "bf16":
        out = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_names).astype(
                jnp.float32
            ),
            grads,
        )
        return out, error_feedback

    # int8 with per-leaf absmax scale and error feedback
    def q(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        qg = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - qg.astype(jnp.float32) * scale
        return qg, scale, err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    qs = [q(g, e) for g, e in zip(flat_g, flat_e)]
    summed = [
        jax.lax.psum(qg.astype(jnp.int32), axis_names).astype(jnp.float32)
        * jax.lax.pmax(scale, axis_names)
        for qg, scale, _ in qs
    ]
    new_ef = jax.tree.unflatten(treedef, [e for _, _, e in qs])
    return jax.tree.unflatten(treedef, summed), new_ef


# ------------------------------------------------------------------ train step
def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh: Optional[Mesh] = None,
    mode: str = "pjit",  # "pjit" (TP+DP, XLA collectives) | "dp_shard_map"
    donate: bool = True,
):
    """Returns a jitted (state, tokens, labels[, frontend]) -> (state, metrics).

    * mesh=None            -- single-device step for tests/examples.
    * mode="pjit"          -- production path: params sharded per
      sharding/specs.py, batch over (pod, data); XLA inserts the gradient
      all-reduce.  This is what the dry-run lowers.
    * mode="dp_shard_map"  -- pure data parallelism over every mesh axis with
      params replicated; the DP gradient all-reduce goes through the explicit
      compression hook (bf16/int8 + error feedback) so collective bytes
      really shrink.  Used by the compression §Perf experiments and suited
      to the <2B archs whose params fit per chip.
    """

    def loss_fn(params, tokens, labels, frontend):
        loss, metrics = M.forward_train(cfg, params, tokens, labels, frontend)
        return loss, metrics

    def accumulate(params, batch):
        tokens, labels, frontend = batch
        mb = tcfg.microbatches
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, tokens, labels, frontend
            )
            return loss, metrics, grads
        Bl = tokens.shape[0]
        assert Bl % mb == 0, (Bl, mb)
        split = lambda t: (
            None if t is None else t.reshape((mb, Bl // mb) + t.shape[1:])
        )
        mtok, mlab, mfe = split(tokens), split(labels), split(frontend)

        def body(carry, xs):
            acc_loss, acc_grads = carry
            tk, lb = xs[0], xs[1]
            fe = xs[2] if len(xs) > 2 else None
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, tk, lb, fe
            )
            acc_grads = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / mb, acc_grads, grads
            )
            return (acc_loss + loss / mb, acc_grads), metrics

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        xs = (mtok, mlab) if mfe is None else (mtok, mlab, mfe)
        (loss, grads), metrics = jax.lax.scan(body, (jnp.zeros(()), zero_g), xs)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def finish(state, loss, metrics, grads, ef):
        grads, gnorm = opt.clip_by_global_norm(grads, tcfg.clip_norm)
        lr = opt.cosine_schedule(
            state.opt.step, tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps
        )
        params, opt_state = opt.adamw_update(
            grads,
            state.opt,
            lr,
            b1=tcfg.b1,
            b2=tcfg.b2,
            weight_decay=tcfg.weight_decay,
            compute_dtype=cfg.param_dtype,
        )
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return TrainState(params, opt_state, ef), metrics

    def step_plain(state: TrainState, tokens, labels, frontend=None):
        loss, metrics, grads = accumulate(state.params, (tokens, labels, frontend))
        return finish(state, loss, metrics, grads, state.error_feedback)

    if mesh is None:
        return jax.jit(step_plain, donate_argnums=(0,) if donate else ())

    if mode == "pjit":
        from repro.sharding import specs

        shardings = specs.train_step_shardings(cfg, mesh)
        return jax.jit(
            step_plain,
            in_shardings=shardings["in"],
            out_shardings=shardings["out"],
            donate_argnums=(0,) if donate else (),
        )

    # ---- dp_shard_map: explicit, compressible DP all-reduce
    axes = tuple(mesh.axis_names)
    ndev = 1
    for a in axes:
        ndev *= mesh.shape[a]

    def step_dp(state: TrainState, tokens, labels, frontend=None):
        loss, metrics, grads = accumulate(state.params, (tokens, labels, frontend))
        grads, ef = _compress_grads(grads, state.error_feedback, tcfg.compression, axes)
        grads = jax.tree.map(lambda g: g / ndev, grads)
        loss = jax.lax.pmean(loss, axes)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axes), metrics)
        return finish(state, loss, metrics, grads, ef)

    batch_spec = P(axes)  # batch dim sharded over every axis
    state_spec = P()  # replicated params/opt
    from repro.sharding.compat import shard_map

    return jax.jit(
        shard_map(
            step_dp,
            mesh=mesh,
            in_specs=(state_spec, batch_spec, batch_spec, None),
            out_specs=(state_spec, P()),
            check=False,
        ),
        donate_argnums=(0,) if donate else (),
    )


# ------------------------------------------------------------------ train loop
def train_loop(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    pipeline,
    steps: int,
    state: Optional[TrainState] = None,
    step0: int = 0,
    key=None,
    callback=None,
):
    """Simple host loop used by examples/ and tests (single process)."""
    key = key if key is not None else jax.random.key(0)
    state = state if state is not None else init_train_state(cfg, tcfg, key)
    step_fn = make_train_step(cfg, tcfg)
    history = []
    for s in range(step0, step0 + steps):
        tokens, labels = pipeline.batch_at(s)
        new_state, metrics = step_fn(state, jnp.asarray(tokens), jnp.asarray(labels))
        state = new_state
        history.append({k: float(v) for k, v in metrics.items()})
        if callback is not None:
            callback(s, state, history[-1])
    return state, history
