from repro.training.train_loop import TrainConfig, TrainState, make_train_step, train_loop

__all__ = ["TrainConfig", "TrainState", "make_train_step", "train_loop"]
