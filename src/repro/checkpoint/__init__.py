from repro.checkpoint.store import (
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.elastic import reshard_state

__all__ = [
    "CheckpointManager",
    "restore_checkpoint",
    "save_checkpoint",
    "reshard_state",
]
