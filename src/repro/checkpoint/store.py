"""Step-tagged atomic checkpointing for restart-after-failure.

Requirements at 1000+-node scale (DESIGN.md §5):
  * atomic: write to a temp dir, fsync, rename -- a preempted save never
    corrupts the latest good checkpoint;
  * self-describing: a manifest records pytree structure, dtypes, mesh shape
    and the data-pipeline step so restore needs no out-of-band state;
  * elastic: leaves are stored UNSHARDED (gathered) in this single-host
    container; restore re-shards onto whatever mesh the surviving slice
    provides (checkpoint/elastic.py).  On a real pod each host would write
    its shard (tensorstore-style); the manifest format already carries the
    mesh so that swap is local to this module;
  * async-capable: ``CheckpointManager(save_async=True)`` snapshots to host
    memory synchronously (cheap) and writes in a background thread so the
    train loop is not blocked by the filesystem.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path arrived after 0.4.x; tree_util always has it.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, state: Any, extra: Optional[dict] = None):
    """Atomic save of an arbitrary pytree under ``directory/step_<N>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    paths, leaves, _ = _flatten_with_paths(state)
    arrays = {}
    dtypes = {}
    for p, leaf in zip(paths, leaves):
        arr = np.asarray(jax.device_get(leaf))
        # bf16 is not a numpy-native dtype for npz portability: view as u16
        if arr.dtype == jnp.bfloat16:
            dtypes[p] = "bfloat16"
            arr = arr.view(np.uint16)
        else:
            dtypes[p] = str(arr.dtype)
        arrays[p] = arr
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        with open(os.path.join(tmp, _ARRAYS), "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "paths": paths,
            "dtypes": dtypes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, like: Any, step: Optional[int] = None
) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays/specs)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, _ARRAYS))
    paths, _, treedef = _flatten_with_paths(like)
    if paths != manifest["paths"]:
        missing = set(manifest["paths"]) ^ set(paths)
        raise ValueError(f"checkpoint/pytree structure mismatch: {sorted(missing)[:5]}")
    leaves = []
    for p in paths:
        arr = data[p]
        if manifest["dtypes"][p] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), step, manifest.get("extra", {})


class CheckpointManager:
    """Rolling checkpoints with optional async writes and retention."""

    def __init__(
        self,
        directory: str,
        keep: int = 3,
        save_async: bool = False,
    ):
        self.directory = directory
        self.keep = keep
        self.save_async = save_async
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        self.wait()
        # Snapshot to host RAM synchronously; device buffers may be donated
        # by the next step.
        snap = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)

        def work():
            try:
                save_checkpoint(self.directory, step, snap, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.save_async:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self.wait()

    def restore(self, like: Any, step: Optional[int] = None):
        return restore_checkpoint(self.directory, like, step)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
