"""Elastic scaling: restore a checkpoint onto a different mesh.

Failure model at 1000+ nodes: a pod (or slice) dies; the job restarts on the
surviving slice with fewer devices (or a repaired, larger one).  Because
checkpoints are stored unsharded-logical (store.py) and sharding specs are
pure functions of (config, mesh) (sharding/specs.py), resharding is just
``device_put`` with the new mesh's NamedShardings -- no format migration.

``reshard_state`` also handles the global-batch bookkeeping: the data
pipeline is stateless in step (data/pipeline.py), so the restored run simply
continues at the checkpointed step with the new host layout.

The straggler/failure *driver* policy (deadlines, slice re-election) lives
in launch/train.py; this module is only the state mechanics, kept separate
so it is unit-testable on CPU with fake device counts.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def reshard_state(state: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """Place a (host/unsharded) TrainState onto ``mesh`` per the specs."""
    from repro.sharding.specs import state_specs

    specs = state_specs(cfg, mesh)

    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(
        put, state, specs, is_leaf=lambda x: x is None
    )


def reshard_params(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    from repro.sharding.specs import param_specs

    specs = param_specs(cfg, mesh)
    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        params,
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
