"""Abstract-eval contract checker (DESIGN.md §10).

Declares the shape/dtype/layout contracts the stack's layers exchange --
``SearchPlan`` and the per-op query outputs (§6), the forest kernel
operands (§2/§8), the delta-buffer quadruple (§7), the sharded program
builders and their replicated-delta / chunk-divisibility / capacity
invariants (§9) -- and verifies them WITHOUT running real workloads:
everything that can be checked abstractly goes through ``jax.eval_shape``
on representative specs (no FLOPs, no device buffers beyond the tiny plan
constants), and the cross-module bounds delegate to
``repro.analysis.invariants`` so the checker and the runtime asserts can
never disagree.

To declare a contract on a NEW op or kernel: add its output row to
``OP_CONTRACTS`` (or extend ``check_*`` below with an ``eval_shape`` over
its entry point) -- the checker fails on any drift between the declared
row and what the code abstractly evaluates to.
"""

from __future__ import annotations

import functools
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis import invariants
from repro.analysis.report import Violation


def _spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# The §6 per-op output contract for a B-lane batch with scan fan-out k:
# op -> tuple of (shape-lambda, dtype).  The single source the engine, the
# distributed runners and the server all must honor (their outputs are
# abstractly evaluated against these rows below).
OP_CONTRACTS = {
    "lookup": (
        (lambda B, k: (B,), jnp.int32),
        (lambda B, k: (B,), jnp.bool_),
    ),
    "predecessor": (
        (lambda B, k: (B,), jnp.int32),
        (lambda B, k: (B,), jnp.int32),
        (lambda B, k: (B,), jnp.bool_),
    ),
    "successor": (
        (lambda B, k: (B,), jnp.int32),
        (lambda B, k: (B,), jnp.int32),
        (lambda B, k: (B,), jnp.bool_),
    ),
    "range_count": ((lambda B, k: (B,), jnp.int32),),
    "range_scan": (
        (lambda B, k: (B, k), jnp.int32),
        (lambda B, k: (B, k), jnp.int32),
        (lambda B, k: (B,), jnp.int32),
    ),
}

# Representative spec sizes: tiny, but non-degenerate (multi-level tree,
# batch > n_trees, k smaller than the key count).
_N_KEYS = 31  # height-4 perfect tree
_BATCH = 8
_K = 4


def _violation(check: str, msg: str) -> Violation:
    return Violation("CON001", f"contracts:{check}", 0, msg)


def _check_outputs(
    check: str, op: str, out, B: int, k: int, errors: List[Violation]
) -> None:
    rows = OP_CONTRACTS[op]
    out = out if isinstance(out, tuple) else (out,)
    if len(out) != len(rows):
        errors.append(
            _violation(
                check,
                f"{op}: {len(out)} outputs, contract declares {len(rows)}",
            )
        )
        return
    for i, (o, (shape_fn, dtype)) in enumerate(zip(out, rows)):
        want = tuple(shape_fn(B, k))
        if tuple(o.shape) != want or o.dtype != jnp.dtype(dtype):
            errors.append(
                _violation(
                    check,
                    f"{op} output[{i}]: {o.dtype}{tuple(o.shape)} != "
                    f"declared {jnp.dtype(dtype)}{want}",
                )
            )


def _tiny_tree():
    from repro.core import tree as tree_lib

    keys = np.arange(1, _N_KEYS + 1, dtype=np.int32) * 3
    return tree_lib.build_tree(keys, keys * 7)


def _delta_spec(capacity: int):
    from repro.core import delta as delta_lib

    return delta_lib.DeltaBuffer(
        keys=_spec((capacity,), jnp.int32),
        values=_spec((capacity,), jnp.int32),
        tombstone=_spec((capacity,), jnp.bool_),
        in_tree=_spec((capacity,), jnp.bool_),
        tree_rank=_spec((capacity,), jnp.int32),
        count=_spec((), jnp.int32),
    )


# ----------------------------------------------------------------- the checks
def check_ordered_packing() -> List[Violation]:
    """OrderedResult field order == the packed-collective lane layout."""
    from repro.core import plans as plans_lib
    from repro.core import tree as tree_lib

    errors: List[Violation] = []
    if tree_lib.OrderedResult._fields != invariants.ORDERED_FIELDS:
        errors.append(
            _violation(
                "packing",
                f"OrderedResult fields {tree_lib.OrderedResult._fields} != "
                f"invariants.ORDERED_FIELDS {invariants.ORDERED_FIELDS}",
            )
        )
        return errors
    res = tree_lib.OrderedResult(
        value=_spec((_BATCH,), jnp.int32),
        found=_spec((_BATCH,), jnp.bool_),
        pred_key=_spec((_BATCH,), jnp.int32),
        pred_value=_spec((_BATCH,), jnp.int32),
        succ_key=_spec((_BATCH,), jnp.int32),
        succ_value=_spec((_BATCH,), jnp.int32),
        rank=_spec((_BATCH,), jnp.int32),
    )
    packed = jax.eval_shape(plans_lib.pack_ordered, res)
    want = (_BATCH, invariants.ORDERED_PACK_WIDTH)
    if tuple(packed.shape) != want or packed.dtype != jnp.int32:
        errors.append(
            _violation(
                "packing",
                f"pack_ordered: {packed.dtype}{tuple(packed.shape)} != "
                f"int32{want} -- the packed all_to_all image drifted",
            )
        )
    else:
        unpacked = jax.eval_shape(plans_lib.unpack_ordered, packed)
        if unpacked.found.dtype != jnp.bool_ or any(
            tuple(f.shape) != (_BATCH,) for f in unpacked
        ):
            errors.append(
                _violation("packing", "unpack_ordered round-trip drifted")
            )
    return errors


def check_plan_layout() -> List[Violation]:
    """SearchPlan operand layout per strategy (§2/§8): one flat level-major
    row of 2^(h+1)-1 int32 nodes; hyb's split level == log2(n_trees)."""
    from repro.core import plans as plans_lib

    errors: List[Violation] = []
    tree = _tiny_tree()
    for strategy, n_trees in (("hrz", 1), ("dup", 4), ("hyb", 4)):
        plan = plans_lib.make_plan(tree, strategy=strategy, n_trees=n_trees)
        rows, n = plan.forest_keys.shape
        try:
            invariants.check_forest_nodes(n, plan.forest_height)
        except ValueError as e:
            errors.append(_violation("plan", f"{strategy}: {e}"))
        if plan.forest_values.shape != plan.forest_keys.shape:
            errors.append(
                _violation("plan", f"{strategy}: keys/values shape mismatch")
            )
        if plan.forest_keys.dtype != jnp.int32:
            errors.append(
                _violation(
                    "plan", f"{strategy}: operands {plan.forest_keys.dtype}"
                )
            )
        if rows != 1:
            errors.append(
                _violation(
                    "plan",
                    f"{strategy}: {rows} operand rows -- the single-chip "
                    "strategies carry ONE flat tree row (DESIGN.md §8)",
                )
            )
        if plan.rank_to_bfs.shape[0] != tree.n_nodes:
            errors.append(
                _violation("plan", f"{strategy}: rank_to_bfs size drifted")
            )
        if strategy == "hyb":
            want_split = invariants.split_level_for(n_trees)
            if plan.split_level != want_split:
                errors.append(
                    _violation(
                        "plan",
                        f"hyb split_level {plan.split_level} != "
                        f"log2(n_trees) {want_split}",
                    )
                )
    return errors


def check_query_contracts() -> List[Violation]:
    """Every (strategy, op, kernel/ref, with/without delta) combination
    abstractly evaluates to the declared §6 output rows.  This is the check
    that catches an epilogue or kernel output drifting shape/dtype."""
    from repro.core import delta as delta_lib
    from repro.core import plans as plans_lib

    errors: List[Violation] = []
    tree = _tiny_tree()
    q = _spec((_BATCH,), jnp.int32)
    dspec = _delta_spec(8)
    for strategy, n_trees in (("hrz", 1), ("dup", 2), ("hyb", 4)):
        plan = plans_lib.make_plan(tree, strategy=strategy, n_trees=n_trees)
        for use_kernel in (False, True):
            for with_delta in (False, True):
                tag = (
                    f"{strategy}/{'kernel' if use_kernel else 'ref'}/"
                    f"{'delta' if with_delta else 'plain'}"
                )
                for op in plans_lib.QUERY_OPS:
                    fn = functools.partial(
                        plans_lib.ordered_query,
                        plan,
                        op,
                        k=_K,
                        use_kernel=use_kernel,
                        interpret=True,
                    )
                    args = (q, q) if op in plans_lib.RANGE_OPS else (q,)
                    try:
                        if with_delta:
                            # the delta spec must be an eval_shape ARGUMENT
                            # (abstract leaves), not a closure constant
                            out = jax.eval_shape(
                                lambda *a, _fn=fn: _fn(*a[:-1], delta=a[-1]),
                                *args,
                                dspec,
                            )
                        else:
                            out = jax.eval_shape(fn, *args)
                    except Exception as e:  # contract: must abstractly eval
                        errors.append(
                            _violation(
                                "query",
                                f"{tag} {op}: eval_shape failed: {e}",
                            )
                        )
                        continue
                    _check_outputs(f"query[{tag}]", op, out, _BATCH, _K, errors)
    # the delta quadruple (§7): four flat (C,) int32 operands
    ops = jax.eval_shape(delta_lib.operands, dspec)
    if len(ops) != invariants.DELTA_OPERANDS or any(
        tuple(o.shape) != (8,) or o.dtype != jnp.int32 for o in ops
    ):
        errors.append(
            _violation(
                "delta",
                f"delta.operands: {[(str(o.dtype), o.shape) for o in ops]} "
                f"!= {invariants.DELTA_OPERANDS} x int32(C,)",
            )
        )
    return errors


def check_invariant_bounds() -> List[Violation]:
    """The shared bounds themselves: good values pass, bad values raise.
    Guards against someone weakening ``invariants`` (both the checker and
    the runtime asserts would silently rot together otherwise)."""
    errors: List[Violation] = []
    cases: Tuple[Tuple[str, Callable[[], object], bool], ...] = (
        ("chunk divides axis", lambda: invariants.check_chunk_divides(8192, 8, "model"), True),
        ("chunk !divides axis", lambda: invariants.check_chunk_divides(100, 8, "model"), False),
        ("delta config ok", lambda: invariants.check_delta_config(64, 48), True),
        ("delta negative cap", lambda: invariants.check_delta_config(-1, None), False),
        ("high water > cap", lambda: invariants.check_delta_config(64, 65), False),
        ("high water zero", lambda: invariants.check_delta_config(64, 0), False),
        ("pow2 ok", lambda: invariants.check_power_of_two(8, "n"), True),
        ("pow2 bad", lambda: invariants.check_power_of_two(6, "n"), False),
        ("capacity_frac bad", lambda: invariants.capacity_for_trace(512, 8, 0.0), False),
        ("forest nodes ok", lambda: invariants.check_forest_nodes(31, 4), True),
        ("forest nodes bad", lambda: invariants.check_forest_nodes(30, 4), False),
    )
    for name, fn, should_pass in cases:
        try:
            fn()
            ok = True
        except ValueError:
            ok = False
        if ok != should_pass:
            errors.append(
                _violation(
                    "bounds",
                    f"invariants self-check {name!r}: "
                    f"{'passed' if ok else 'raised'}, expected "
                    f"{'pass' if should_pass else 'raise'}",
                )
            )
    # capacity_frac bounds over a representative grid: 1 <= cap <= B, and
    # depth doubles when the traced batch doubles (the lo||hi property).
    for B in (8, 512, 8192):
        for M in (1, 2, 8):
            for frac in (0.25, 1.0, 2.0):
                cap = invariants.capacity_for_trace(B, M, frac)
                if not 1 <= cap <= B:
                    errors.append(
                        _violation(
                            "bounds",
                            f"capacity_for_trace({B}, {M}, {frac}) = {cap} "
                            f"outside [1, {B}]",
                        )
                    )
    # high-water default stays inside (0, capacity]
    for cap in (1, 4, 64, 8192):
        hw = invariants.resolved_high_water(cap, None)
        if not 1 <= hw <= cap:
            errors.append(
                _violation(
                    "bounds",
                    f"resolved_high_water({cap}) = {hw} outside [1, {cap}]",
                )
            )
    return errors


def check_engine_delegation() -> List[Violation]:
    """EngineConfig/BSTServer must enforce the shared bounds (the
    delegation the bugfix sweep installed): constructing with values the
    invariants reject must raise ValueError."""
    from repro.core.engine import EngineConfig
    from repro.serving.bst_server import BSTServer

    errors: List[Violation] = []
    for kwargs in ({"delta_capacity": -1}, {"delta_capacity": 8, "delta_high_water": 9}):
        try:
            EngineConfig(**kwargs)
            errors.append(
                _violation(
                    "delegation", f"EngineConfig({kwargs}) did not raise"
                )
            )
        except ValueError:
            pass
    # chunk/mesh divisibility: exercised abstractly via the shared check
    # (constructing a real mesh here would need forced devices); the
    # server's constructor path is covered by tests/test_analysis.py.
    del BSTServer
    return errors


def check_sharded_builders() -> List[Violation]:
    """The §9 sharded-builder contract on the current (possibly 1-device)
    host: mesh axis naming per strategy, the replicated delta operand
    specs, capacity sizing, and the run(op, ...) outputs against the §6
    rows -- executed on a tiny tree, so this stays cheap."""
    from repro.core import delta as delta_lib
    from repro.core import distributed as dist_lib
    from repro.core import plans as plans_lib

    errors: List[Violation] = []
    # the replicated-delta layout is a module-level constant now: verify
    # every spec is fully replicated (P() with no named axes)
    specs = dist_lib.DELTA_IN_SPECS
    if len(specs) != invariants.DELTA_OPERANDS or any(
        tuple(s) != tuple(P()) for s in specs
    ):
        errors.append(
            _violation(
                "sharded",
                f"DELTA_IN_SPECS {specs} != {invariants.DELTA_OPERANDS} "
                "fully-replicated P() entries -- the delta buffer must be "
                "REPLICATED on every chip (DESIGN.md §9)",
            )
        )
    for strategy in plans_lib.SHARDED_STRATEGIES:
        axis = plans_lib.mesh_axis_for_strategy(strategy)
        want = "data" if strategy == "dup" else "model"
        if axis != want:
            errors.append(
                _violation(
                    "sharded", f"{strategy} shards over {axis!r}, want {want!r}"
                )
            )
        mesh = dist_lib.make_serving_mesh(strategy, devices=jax.devices()[:1])
        if mesh.axis_names != (axis,):
            errors.append(
                _violation(
                    "sharded",
                    f"make_serving_mesh({strategy!r}) axes {mesh.axis_names}",
                )
            )
        tree = _tiny_tree()
        run = dist_lib.make_sharded_query(tree, mesh, strategy, use_kernel=False)
        # per-device stored nodes: the subtree shard plus the replicated
        # register layer (< axis size nodes) -- an M-fold replication
        # regression of a PARTITIONED operand blows straight through this.
        bound = tree.n_nodes + mesh.shape[axis]
        if run.device_nodes > bound:
            errors.append(
                _violation(
                    "sharded",
                    f"{strategy}: {run.device_nodes} stored nodes/device > "
                    f"single-chip bound {bound}",
                )
            )
        q = jnp.arange(_BATCH, dtype=jnp.int32) * 3 + 1
        delta = delta_lib.empty(8)
        for op in plans_lib.QUERY_OPS:
            args = (q, q) if op in plans_lib.RANGE_OPS else (q,)
            for kw in ({}, {"delta": delta}):
                out = run(op, *args, k=_K, **kw)
                _check_outputs(
                    f"sharded[{strategy}/{'delta' if kw else 'plain'}]",
                    op,
                    out,
                    _BATCH,
                    _K,
                    errors,
                )
    return errors


ALL_CHECKS = (
    check_ordered_packing,
    check_plan_layout,
    check_query_contracts,
    check_invariant_bounds,
    check_engine_delegation,
    check_sharded_builders,
)


def run_contracts() -> List[Violation]:
    errors: List[Violation] = []
    for check in ALL_CHECKS:
        errors.extend(check())
    return errors
