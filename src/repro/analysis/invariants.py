"""Shared cross-module invariants: ONE definition, two consumers.

Every check here is imported both by the production code that must fail
loudly at runtime (``EngineConfig.__post_init__``, ``BSTServer``
construction, the sharded program builders) and by the static contract
checker (``repro.analysis.contracts``) that verifies the same properties
on representative specs in CI.  That is the whole point of the module: a
bound that lives only in a runtime assert drifts; a bound that lives only
in a checker rots.  Keep this file PURE -- stdlib only, no jax, no
repro imports -- so ``core``/``serving``/``kernels`` can depend on it
without cycles.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

# The §6 ordered-query payload: the field order of ``tree.OrderedResult``
# and the lane width of ``plans.pack_ordered``'s packed collective image
# are the same contract seen from two sides (DESIGN.md §9).  The contract
# checker asserts the NamedTuple and the packing honor this tuple.
ORDERED_FIELDS: Tuple[str, ...] = (
    "value",
    "found",
    "pred_key",
    "pred_value",
    "succ_key",
    "succ_value",
    "rank",
)
ORDERED_PACK_WIDTH: int = len(ORDERED_FIELDS)

# The delta buffer rides every query as this many flat (C,) int32 operands
# -- sorted keys, values, tombstone flags, signed rank weights (DESIGN.md
# §7) -- replicated on every device in sharded mode (§9).
DELTA_OPERANDS: int = 4


def check_power_of_two(n: int, what: str) -> int:
    """Validate ``n`` is a positive power of two; returns ``log2(n)``."""
    if n < 1 or (n & (n - 1)):
        raise ValueError(f"{what} must be a positive power of two (got {n})")
    return n.bit_length() - 1


def split_level_for(n_trees: int) -> int:
    """The hybrid split level: ``log2(n_trees)`` vertical subtrees hang off
    the register layer, so the subtree count must be a power of two."""
    return check_power_of_two(n_trees, "n_trees")


def check_forest_nodes(n_nodes: int, height: int) -> None:
    """A flat level-major operand stores the FULL perfect tree."""
    if n_nodes != (1 << (height + 1)) - 1:
        raise ValueError(
            f"flat operand has {n_nodes} nodes, want 2^{height + 1}-1"
        )


def check_chunk_divides(chunk_size: int, n_shards: int, axis: str) -> None:
    """Sharded programs are fixed-shape SPMD: an unpadded chunk whose batch
    does not divide over the axis has no legal placement, so the contract
    fails loudly at construction instead of deep inside shard_map
    (DESIGN.md §9)."""
    if chunk_size % n_shards:
        raise ValueError(
            f"chunk_size={chunk_size} must be divisible by the mesh "
            f"axis {axis!r} size {n_shards} -- sharded chunks split "
            "evenly across devices"
        )


def check_delta_config(
    delta_capacity: int, delta_high_water: Optional[int]
) -> None:
    """The write-path capacity bounds (DESIGN.md §7)."""
    if delta_capacity < 0:
        raise ValueError(
            f"delta_capacity must be >= 0 (got {delta_capacity}); "
            "0 disables the write path"
        )
    if (
        delta_capacity > 0
        and delta_high_water is not None
        and not 1 <= delta_high_water <= delta_capacity
    ):
        raise ValueError(
            f"delta_high_water={delta_high_water} must lie in "
            f"[1, delta_capacity={delta_capacity}] -- a mark above "
            "the capacity could never trigger compaction and the buffer "
            "would overflow"
        )


def resolved_high_water(delta_capacity: int, delta_high_water: Optional[int]) -> int:
    """The compaction trigger: explicit mark, else 3/4 of the capacity."""
    if delta_high_water is not None:
        return delta_high_water
    return max(1, (3 * delta_capacity) // 4)


def capacity_for_trace(batch: int, n_shards: int, capacity_frac: float) -> int:
    """Per-(src,dst) dispatch-buffer depth sized PER TRACE: the local
    batch's fair share ``batch / n_shards`` scaled by the fraction, clamped
    to ``[1, batch]`` (a depth above the batch is stall-free anyway, and a
    zero depth could never place a key).  The concatenated ``lo || hi``
    range traces see 2x the lanes and get 2x the depth, keeping the slack a
    real constant across ops (DESIGN.md §9)."""
    if capacity_frac <= 0:
        raise ValueError(f"capacity_frac must be > 0 (got {capacity_frac})")
    return max(1, min(batch, int(math.ceil(batch / n_shards * capacity_frac))))


def buffer_capacity(chunk: int, n_trees: int, buffer_slack: float) -> int:
    """Single-chip twin of ``capacity_for_trace``: per-subtree dispatch
    depth for a ``chunk``-lane frontend (``plans.hyb_capacity``)."""
    if buffer_slack <= 0:
        raise ValueError(f"buffer_slack must be > 0 (got {buffer_slack})")
    return max(1, int(math.ceil(chunk / n_trees * buffer_slack)))
