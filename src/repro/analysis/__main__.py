"""CLI: ``python -m repro.analysis [paths...] [flags]`` (DESIGN.md §10).

Default run = the static passes (lint + contracts + dead-code drift);
``--serve-gate`` adds the runtime retrace/transfer gate (a real sharded
``BSTServer`` drain per strategy, so it costs seconds, not millis).
``--report-dead`` prints the full reachability classification instead of
just gating it.  ``--report FILE`` writes the static-report/v1 JSON
artifact CI uploads alongside the BENCH json.

Exit code: 0 iff every selected pass is clean; otherwise the full
violation inventory prints and the process exits 1 (never first-failure —
one CI run shows everything).
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analysis import deadcode, lint, report

DEFAULT_PATHS = (
    "src/repro/core",
    "src/repro/kernels",
    "src/repro/serving",
    "src/repro/launch",
)


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker + hot-path lint for the "
        "Pallas forest stack",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help=f"files/dirs to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument(
        "--repo-root",
        default=os.getcwd(),
        help="repo root for the dead-code graph (default: cwd)",
    )
    ap.add_argument(
        "--allowlist",
        default=lint.DEFAULT_ALLOWLIST,
        help="lint allowlist file (default: analysis/allowlist.txt)",
    )
    ap.add_argument(
        "--skip-contracts",
        action="store_true",
        help="lint/dead-code only (no jax import, sub-second)",
    )
    ap.add_argument(
        "--report-dead",
        action="store_true",
        help="print the full module reachability classification",
    )
    ap.add_argument(
        "--serve-gate",
        action="store_true",
        help="also run the runtime retrace/transfer gate (real sharded "
        "BSTServer drains on hrz/dup/hyb)",
    )
    ap.add_argument(
        "--report",
        metavar="FILE",
        help="write the static-report/v1 JSON artifact",
    )
    args = ap.parse_args(argv)

    hard: List[report.Violation] = []
    passes: List[str] = []

    lint_paths = args.paths or [
        os.path.join(args.repo_root, p) for p in DEFAULT_PATHS
    ]
    lint_hard, lint_soft = lint.lint_paths(lint_paths, args.allowlist)
    hard.extend(lint_hard)
    passes.append(f"lint ({len(lint_soft)} allowlisted)")

    dead_hard, classes = deadcode.report_dead(args.repo_root)
    hard.extend(dead_hard)
    passes.append(f"deadcode ({len(classes)} quarantined/unreachable)")
    if args.report_dead:
        quarantine = deadcode.load_quarantine()
        for mod, kind in sorted(classes.items()):
            note = quarantine.get(mod, "<NO QUARANTINE ENTRY>")
            print(f"dead-code {kind}: {mod} -- {note}")
        if not classes:
            print("dead-code: every module reachable from an executable root")

    if not args.skip_contracts:
        from repro.analysis import contracts

        hard.extend(contracts.run_contracts())
        passes.append("contracts")

    if args.serve_gate:
        from repro.analysis import gate

        hard.extend(gate.run_serve_gates())
        passes.append("serve-gate (hrz/dup/hyb)")

    if args.report:
        report.write_json(
            args.report,
            report.to_doc(hard, lint_soft, extra={"passes": passes}),
        )
        print(f"wrote {args.report}")

    try:
        report.gate_violations(hard, "static checks OK: " + ", ".join(passes))
    except SystemExit as e:
        print(e, file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
