"""Dead-code report: which ``repro`` modules nothing reachable imports.

A static import graph over ``src/repro`` plus the executable roots
(``launch/*``, ``benchmarks/``, ``examples/``, ``scripts/``), walked from
those roots.  Modules reachable only through a package ``__init__``
re-export (a weak edge) or only from ``tests/`` are classified
``TEST_ONLY``; modules reachable from nothing are ``DEAD``.  Both require
an entry in ``quarantine.txt`` (same directory as this file) naming why
they stay -- delete the module or write the tracking note, the gate
accepts nothing in between.

The walker is deliberately simple (top-level + function-local ``import``
statements, no importlib tricks); its job is drift detection on THIS
repo's plain import style, not general Python resolution.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Set, Tuple

from repro.analysis.report import Violation

QUARANTINE_FILE = os.path.join(os.path.dirname(__file__), "quarantine.txt")


def _module_name(path: str, src_root: str) -> str:
    rel = os.path.relpath(path, src_root)
    mod = rel[:-3].replace(os.sep, ".")
    return mod[: -len(".__init__")] if mod.endswith(".__init__") else mod


def _imports_of(path: str) -> Set[str]:
    """Every dotted module mentioned in import statements, best effort.

    ``importlib.import_module(f"pkg.prefix.{name}")`` registers as the
    wildcard ``pkg.prefix.*`` -- the config registry's dynamic loading
    keeps its per-architecture modules alive.
    """
    with open(path) as f:
        try:
            tree = ast.parse(f.read(), filename=path)
        except SyntaxError:
            return set()
    found: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                found.add(alias.name)
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            found.add(node.module)
            # ``from pkg import name`` may bind the submodule pkg.name.
            for alias in node.names:
                found.add(f"{node.module}.{alias.name}")
        elif isinstance(node, ast.Call):
            fn = node.func
            is_import_module = (
                isinstance(fn, ast.Attribute) and fn.attr == "import_module"
            ) or (isinstance(fn, ast.Name) and fn.id == "import_module")
            if is_import_module and node.args:
                arg = node.args[0]
                if (
                    isinstance(arg, ast.JoinedStr)
                    and arg.values
                    and isinstance(arg.values[0], ast.Constant)
                ):
                    found.add(str(arg.values[0].value).rstrip(".") + ".*")
                elif isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                    found.add(arg.value)
    return found


def build_graph(
    repo_root: str,
) -> Tuple[Dict[str, str], Dict[str, Set[str]], Dict[str, Set[str]]]:
    """(module -> file, module -> deps, module -> strong deps) over
    src/repro.  Strong deps are the dynamic-import wildcards: real
    call-path dependencies even when they sit in a package ``__init__``
    whose plain re-export edges the walker treats as weak."""
    src_root = os.path.join(repo_root, "src")
    files: Dict[str, str] = {}
    for dirpath, _dirnames, filenames in os.walk(os.path.join(src_root, "repro")):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                files[_module_name(path, src_root)] = path
    edges: Dict[str, Set[str]] = {}
    strong: Dict[str, Set[str]] = {}
    for mod, path in files.items():
        raw = _imports_of(path)
        deps = _resolve(raw, files)
        strong[mod] = _resolve({n for n in raw if n.endswith(".*")}, files)
        # importing any submodule imports its parent packages first
        parent = mod.rsplit(".", 1)[0]
        if parent in files:
            deps.add(parent)
        edges[mod] = deps - {mod}
    return files, edges, strong


def _resolve(names: Set[str], files: Dict[str, str]) -> Set[str]:
    """Map raw import names to known modules: longest known prefix wins
    (pkg.sub.attr -> pkg.sub); ``pkg.prefix.*`` wildcards fan out to every
    module under the prefix; stdlib/third-party names drop out."""
    deps: Set[str] = set()
    for name in names:
        if name.endswith(".*"):
            prefix = name[:-1]  # keep the trailing dot
            deps.update(m for m in files if m.startswith(prefix))
            continue
        parts = name.split(".")
        for cut in range(len(parts), 0, -1):
            cand = ".".join(parts[:cut])
            if cand in files:
                deps.add(cand)
                break
    return deps


def _dir_imports(dirs, files: Dict[str, str]) -> Set[str]:
    """repro modules imported by loose .py files in the given directories."""
    found: Set[str] = set()
    for d in dirs:
        if not os.path.isdir(d):
            continue
        for fn in sorted(os.listdir(d)):
            if fn.endswith(".py"):
                found |= _resolve(_imports_of(os.path.join(d, fn)), files)
    return found


def _reach(
    seeds: Set[str],
    edges: Dict[str, Set[str]],
    weak: Set[str],
    strong: Dict[str, Set[str]],
) -> Set[str]:
    """Transitive closure.  Out of weak (package ``__init__``) nodes only
    the strong (dynamic-import) edges are followed: a module reachable
    only because a package re-exports it is not pulled in by real
    call-path imports, but a registry that ``import_module``s its
    submodules genuinely loads them."""
    seen: Set[str] = set()
    todo = list(seeds)
    while todo:
        mod = todo.pop()
        if mod in seen:
            continue
        seen.add(mod)
        if mod in weak and mod not in seeds:
            todo.extend(strong.get(mod, ()))
            continue
        todo.extend(edges.get(mod, ()))
    return seen


def load_quarantine(path: str = QUARANTINE_FILE) -> Dict[str, str]:
    """``<module> <reason...>`` lines; '#' comments and blanks skipped."""
    entries: Dict[str, str] = {}
    if not os.path.exists(path):
        return entries
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            mod, _, reason = line.partition(" ")
            entries[mod] = reason.strip()
    return entries


def dead_modules(repo_root: str) -> Dict[str, str]:
    """module -> classification ('DEAD' | 'TEST_ONLY') for unreachable code.

    Roots: the ``launch`` entry points (the CLI surface), plus everything
    ``benchmarks/``, ``examples/`` and ``scripts/`` import.  ``analysis``
    is its own root (this tool and CI invoke it directly).
    """
    files, edges, strong = build_graph(repo_root)
    weak = {m for m, p in files.items() if p.endswith("__init__.py")}
    seeds = {m for m in files if m.startswith(("repro.launch", "repro.analysis"))}
    seeds |= _dir_imports(
        (os.path.join(repo_root, d) for d in ("benchmarks", "examples", "scripts")),
        files,
    )
    reachable = _reach(seeds, edges, weak, strong)
    test_seeds = _dir_imports((os.path.join(repo_root, "tests"),), files)
    test_reach = _reach(test_seeds | seeds, edges, set(), strong)
    out: Dict[str, str] = {}
    for mod in sorted(files):
        if mod in reachable:
            continue
        out[mod] = "TEST_ONLY" if mod in test_reach else "DEAD"
    return out


def report_dead(repo_root: str) -> Tuple[List[Violation], Dict[str, str]]:
    """Gate form: unreachable modules missing a quarantine entry are
    violations; returns (violations, full classification map)."""
    quarantine = load_quarantine()
    classes = dead_modules(repo_root)
    errors: List[Violation] = []
    for mod, kind in classes.items():
        if mod in quarantine:
            continue
        errors.append(
            Violation(
                "DEAD001",
                mod.replace(".", "/") + ".py",
                0,
                f"{kind}: no executable root imports this module -- delete "
                "it or add a tracked entry to analysis/quarantine.txt",
            )
        )
    for mod in quarantine:
        if mod not in classes:
            errors.append(
                Violation(
                    "DEAD002",
                    "src/repro/analysis/quarantine.txt",
                    0,
                    f"stale quarantine entry {mod!r}: the module is now "
                    "reachable (or gone) -- remove the entry",
                )
            )
    return errors, classes
