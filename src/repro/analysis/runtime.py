"""Runtime-assisted retrace/transfer detection (DESIGN.md §10).

Two instruments, both cheap enough to wrap real serving code:

  * ``compile_watch()`` -- compile-cache instrumentation: flips
    ``jax_log_compiles`` and captures the "Compiling <name> ..." records
    jax's dispatch/pxla loggers emit once per (program, shape) compile.
    A jit cache hit emits nothing, so a steady-state region that compiles
    ANYTHING is a retrace by definition -- content-dependent shapes,
    unhashable statics and fresh-function-per-call bugs all surface here.
  * ``transfer_watch()`` -- ``jax.transfer_guard`` wiring plus the
    planned-fetch budget.  Implicit host->device transfers raise under
    the guard on every backend.  Implicit device->host conversions are
    NOT interceptable from Python on the CPU backend (jaxlib's ArrayImpl
    serves numpy through the C buffer protocol, and host-resident buffers
    make the d2h guard a no-op), so the d2h side is enforced by
    construction instead: every PLANNED fetch on the hot path goes
    through ``device_fetch`` (the one sanctioned spelling, budgeted by
    lint rule ANA006), the watcher counts those, and the serve gate
    asserts the count matches the drain's exact retire budget.  Anything
    pulled outside ``device_fetch`` is a lint violation (ANA005); on a
    real TPU backend the same ``transfer_guard`` wiring additionally
    raises on it at runtime.

``device_fetch`` lives here -- importable by ``core``/``serving`` without
cycles (this module depends only on jax + stdlib).
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
from typing import Iterator, List

import jax

# Loggers that emit one WARNING record per actual compilation.  The pxla
# one carries "Compiling <fn> with global shapes and types [...]" for
# every lowered program (jit and shard_map alike) on jax 0.4.x.
_COMPILE_LOGGERS = (
    "jax._src.interpreters.pxla",
    "jax._src.dispatch",
)
_COMPILE_PREFIXES = ("Compiling ",)

_fetch_count_lock = threading.Lock()
_fetch_count = 0


def device_fetch(value):
    """The sanctioned device->host fetch (DESIGN.md §10).

    Semantically ``jax.device_get`` -- numpy arrays and pytrees pass
    through -- but counted, so the runtime gate can assert that a
    steady-state drain performs EXACTLY its planned number of fetches and
    nothing more.  Hot-path code must use this (or ``jax.device_get``)
    instead of ``np.asarray``/``int()`` on device values; lint rule
    ANA006 requires each call site to carry an allowlist entry naming its
    budget.
    """
    global _fetch_count
    with _fetch_count_lock:
        _fetch_count += 1
    return jax.device_get(value)


def fetch_count() -> int:
    """Total ``device_fetch`` calls this process (monotonic counter)."""
    return _fetch_count


@dataclasses.dataclass
class CompileRecord:
    logger: str
    message: str


class CompileWatch:
    """Captured compile events; ``count`` == number of programs compiled."""

    def __init__(self) -> None:
        self.records: List[CompileRecord] = []

    @property
    def count(self) -> int:
        return len(self.records)

    def messages(self) -> List[str]:
        return [r.message for r in self.records]


class _CaptureHandler(logging.Handler):
    def __init__(self, watch: CompileWatch, logger_name: str) -> None:
        super().__init__(level=logging.DEBUG)
        self._watch = watch
        self._logger_name = logger_name

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith(_COMPILE_PREFIXES):
            self._watch.records.append(
                CompileRecord(self._logger_name, msg.split("\n", 1)[0])
            )


@contextlib.contextmanager
def compile_watch() -> Iterator[CompileWatch]:
    """Capture every compilation inside the block.

    Zero records over a region means every program the region ran was
    already in the jit cache -- the steady-state contract.  The handler
    swallows the records (propagation off) so gated serving loops do not
    spray WARNINGs to stderr.
    """
    watch = CompileWatch()
    prev_flag = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    attached = []
    for name in _COMPILE_LOGGERS:
        logger = logging.getLogger(name)
        handler = _CaptureHandler(watch, name)
        logger.addHandler(handler)
        attached.append((logger, handler, logger.propagate, logger.level))
        logger.propagate = False
        if logger.level > logging.WARNING or logger.level == logging.NOTSET:
            logger.setLevel(logging.WARNING)
    try:
        yield watch
    finally:
        for logger, handler, propagate, level in attached:
            logger.removeHandler(handler)
            logger.propagate = propagate
            logger.setLevel(level)
        jax.config.update("jax_log_compiles", prev_flag)


@dataclasses.dataclass
class TransferWatch:
    """Fetches observed (via ``device_fetch``) inside a ``transfer_watch``."""

    fetches_before: int = 0

    @property
    def fetches(self) -> int:
        return fetch_count() - self.fetches_before


@contextlib.contextmanager
def transfer_watch() -> Iterator[TransferWatch]:
    """Forbid implicit transfers; count sanctioned fetches.

    Implicit host->device raises immediately (every backend).  Implicit
    device->host raises on backends with device-resident buffers (TPU/GPU)
    -- on CPU it is physically free and invisible, which is exactly why
    planned fetches must route through ``device_fetch`` (counted here) and
    implicit pulls are a STATIC lint violation (ANA005).  Explicit
    ``jax.device_put`` / ``jax.device_get`` stay legal under "disallow":
    the contract bans *unplanned* movement, not movement.
    """
    watch = TransferWatch(fetches_before=fetch_count())
    with jax.transfer_guard_host_to_device("disallow"), \
            jax.transfer_guard_device_to_host("disallow"):
        yield watch
