"""AST lint: the JAX footguns that cost this repo throughput (DESIGN.md §10).

Static rules, tuned to this codebase's hot path (``core/``, ``kernels/``,
``serving/``, ``launch/``).  Analysis is per-function and deliberately
shallow -- single-module, no dataflow across calls -- because every rule
here is a *pattern* gate: it must be cheap, deterministic and explainable
in one line.  The runtime gate (``repro.analysis.gate``) covers what
static patterns cannot (an actual steady-state drain must compile nothing
and move nothing unplanned).

Rules:
  ANA001 tracer-control-flow  -- ``if``/``while``/``assert``/``bool()`` on
         an expression holding a traced value: a silent host sync outside
         jit, a ``TracerBoolConversionError`` (or a retrace-per-value
         trap) inside.
  ANA002 host-op-in-jit       -- ``np.asarray``/``np.array``/``.item()``/
         ``.tolist()``/``jax.device_get``/``print`` inside a function that
         is jitted or shard_map'd: each is a hidden transfer or a
         trace-time constant fold that breaks the compiled program.
  ANA003 kernel-host-op       -- host/numpy ops, ``jnp.asarray`` or
         dynamic-shape jnp calls inside a Pallas kernel body (operands
         arrive as refs; loads/stores are ``pl.*``/ref ops), and rebinding
         a ``*_ref`` parameter instead of storing through it.
  ANA004 retrace-hazard       -- ``jax.jit`` called inside a loop body (a
         fresh cache entry per iteration), mutable default arguments on a
         jitted function, and ``static_argnames`` naming a parameter with
         an unhashable (dict/list/set) default: all silent retraces.
  ANA005 implicit-host-pull   -- ``int()``/``float()``/``np.asarray()``/
         ``.item()``/... on a value produced by a jitted function or a
         ``jnp``/``lax`` call: an implicit device->host sync on the hot
         path.  The sanctioned spelling is ``analysis.runtime.device_fetch``
         (or ``jax.device_get``), which rule ANA006 budgets.
  ANA006 explicit-sync-budget -- every EXPLICIT fetch
         (``jax.device_get``/``device_fetch``) in ``core/``/``kernels/``/
         ``serving/`` must be allowlisted: planned sync points are part of
         the design (the ONE-sync-per-compaction budget, the per-chunk
         retire fetch) and anything else is a new hot-path stall.

The allowlist file (``analysis/allowlist.txt``) carries
``<path-glob> <rule|*> <reason>`` lines; seed modules off the serving path
are allowlisted wholesale, sanctioned syncs per rule (DESIGN.md §10).
"""

from __future__ import annotations

import ast
import fnmatch
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.report import Violation

# Module attributes whose call results live on device: taint sources for
# ANA001/ANA005.  ``jnp``/``jax.lax``/``jax.random`` calls are matched
# structurally; these NAMES cover the repo's own device-returning APIs
# (the jitted kernels wrappers and the engine/serving internals), because
# single-module analysis cannot see across imports.  Extend this set when
# a new device-returning entry point joins the hot path (DESIGN.md §10).
DEVICE_APIS: Set[str] = {
    "bst_search_forest",
    "bst_ordered_forest",
    "bst_hybrid_forest",
    "bst_search",
    "bst_delta_resolve",
    "queue_dispatch",
    "flash_attention",
    "query",
    "_query_chunk",
    "_squery",
    "_ingest",
    "device_put",
}

# jnp calls whose output shape depends on input VALUES: inside a kernel or
# a jitted body these either fail to lower or force a retrace per content.
DYNAMIC_SHAPE_CALLS = {"nonzero", "flatnonzero", "unique", "argwhere", "where1"}

HOST_PULL_METHODS = {"item", "tolist"}
HOST_PULL_FUNCS = {"int", "float", "bool"}
EXPLICIT_SYNC_DIRS = ("/core/", "/kernels/", "/serving/")


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.cond' for Attribute chains, 'jit' for Names, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jnp_call(call: ast.Call) -> bool:
    d = _dotted(call.func)
    if not d:
        return False
    return (
        d.startswith("jnp.")
        or d.startswith("jax.lax.")
        or d.startswith("lax.")
        or d.startswith("jax.random.")
        or d.startswith("jax.nn.")
    )


def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit / jit / functools.partial(jax.jit, ...) as an expression."""
    d = _dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        d = _dotted(node.func)
        if d in ("jax.jit", "jit"):
            return True
        if d in ("functools.partial", "partial") and node.args:
            return _is_jit_expr(node.args[0])
    return False


class _FnInfo:
    def __init__(self, node: ast.AST, parent: Optional["_FnInfo"]):
        self.node = node
        self.parent = parent
        self.jit = False
        self.kernel = False


class Linter:
    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.violations: List[Violation] = []
        # names of module functions wrapped by jax.jit anywhere (decorator,
        # ``f = jax.jit(g)``, ``jax.jit(self.meth)``, shard_map(fn, ...))
        self.jitted_names: Set[str] = set()
        self.kernel_names: Set[str] = set()
        self._collect_wrappers()

    # ------------------------------------------------------------ discovery
    def _collect_wrappers(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            args = node.args
            if d in ("jax.jit", "jit", "shard_map", "jax.experimental.shard_map.shard_map"):
                if args:
                    self._mark(args[0], self.jitted_names)
            elif d in ("functools.partial", "partial") and args:
                if _is_jit_expr(node.args[0]) and len(args) > 1:
                    self._mark(args[1], self.jitted_names)
                # functools.partial(_some_kernel, ...) fed to pallas_call
                inner = _dotted(args[0])
                if inner and inner.rsplit(".", 1)[-1].endswith("_kernel"):
                    self.kernel_names.add(inner.rsplit(".", 1)[-1])
            elif d in ("pl.pallas_call", "pallas_call") and args:
                self._mark(args[0], self.kernel_names)

    @staticmethod
    def _mark(expr: ast.AST, into: Set[str]) -> None:
        d = _dotted(expr)
        if d:
            into.add(d.rsplit(".", 1)[-1])
        elif isinstance(expr, ast.Call):
            # partial(fn, ...) / jax.jit(fn) nested one level
            dd = _dotted(expr.func)
            if dd in ("functools.partial", "partial") and expr.args:
                Linter._mark(expr.args[0], into)

    def _fn_context(self, fn: ast.AST, parent: Optional[_FnInfo]) -> _FnInfo:
        info = _FnInfo(fn, parent)
        name = getattr(fn, "name", "<lambda>")
        args = getattr(fn.args, "args", [])
        if name.endswith("_kernel") or any(
            a.arg.endswith("_ref") or a.arg.endswith("_scr") for a in args
        ):
            info.kernel = True
        if name in self.kernel_names:
            info.kernel = True
        if name in self.jitted_names:
            info.jit = True
        for dec in getattr(fn, "decorator_list", []):
            if _is_jit_expr(dec):
                info.jit = True
        if parent is not None:
            info.jit = info.jit or parent.jit
            info.kernel = info.kernel or parent.kernel
        return info

    # ------------------------------------------------------------------ run
    def run(self) -> List[Violation]:
        self._walk_body(self.tree.body, parent=None)
        return self.violations

    def _walk_body(self, body: Sequence[ast.stmt], parent: Optional[_FnInfo]):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = self._fn_context(stmt, parent)
                self._check_function(stmt, info)
                self._walk_body(stmt.body, info)
            elif isinstance(stmt, ast.ClassDef):
                self._walk_body(stmt.body, parent)
            else:
                # module-level statements: still subject to the loop rule
                self._check_stmt_shallow(stmt, parent)
                for sub in ast.iter_child_nodes(stmt):
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        info = self._fn_context(sub, parent)
                        self._check_function(sub, info)
                        self._walk_body(sub.body, info)

    def _check_stmt_shallow(self, stmt: ast.stmt, parent: Optional[_FnInfo]):
        for node in ast.walk(stmt):
            if isinstance(node, (ast.For, ast.While)):
                self._check_jit_in_loop(node)

    def _add(self, rule: str, node: ast.AST, msg: str) -> None:
        self.violations.append(
            Violation(rule, self.path, getattr(node, "lineno", 0), msg)
        )

    # ------------------------------------------------------ per-function pass
    def _check_function(self, fn: ast.AST, info: _FnInfo) -> None:
        tainted: Set[str] = set()
        self._check_defaults(fn, info)
        for node in self._own_nodes(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                self._track_taint(node, tainted)
            if isinstance(node, (ast.If, ast.While)):
                self._check_tracer_test(node.test, tainted, "if/while")
            if isinstance(node, ast.Assert):
                self._check_tracer_test(node.test, tainted, "assert")
            if isinstance(node, (ast.For, ast.While)):
                self._check_jit_in_loop(node)
            if isinstance(node, ast.Call):
                self._check_call(node, info, tainted)

    def _own_nodes(self, fn: ast.AST):
        """Walk the function body but stop at nested function boundaries
        (nested defs get their own pass with inherited context)."""
        stack = list(fn.body)
        while stack:
            node = stack.pop(0)
            yield node
            for child in ast.iter_child_nodes(node):
                if not isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
                ):
                    stack.append(child)

    # ------------------------------------------------------------ taint model
    def _is_device_expr(self, expr: ast.AST, tainted: Set[str]) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in tainted
        if isinstance(expr, ast.Call):
            if _is_jnp_call(expr):
                return True
            d = _dotted(expr.func)
            if d:
                leaf = d.rsplit(".", 1)[-1]
                if leaf in DEVICE_APIS or leaf in self.jitted_names:
                    return True
            return False
        if isinstance(expr, ast.Attribute):
            # Array metadata is host-side: int(x.shape[0]) is not a pull.
            if expr.attr in ("shape", "dtype", "ndim", "size", "sharding"):
                return False
            return self._is_device_expr(expr.value, tainted)
        if isinstance(expr, (ast.Subscript, ast.Starred)):
            return self._is_device_expr(expr.value, tainted)
        if isinstance(expr, ast.BinOp):
            return self._is_device_expr(expr.left, tainted) or self._is_device_expr(
                expr.right, tainted
            )
        if isinstance(expr, (ast.Tuple, ast.List)):
            return any(self._is_device_expr(e, tainted) for e in expr.elts)
        return False

    def _track_taint(self, node: ast.stmt, tainted: Set[str]) -> None:
        if isinstance(node, ast.AugAssign):
            return
        value = node.value
        if value is None:
            return
        is_dev = self._is_device_expr(value, tainted)
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for tgt in targets:
            names = []
            if isinstance(tgt, ast.Name):
                names = [tgt.id]
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                names = [e.id for e in tgt.elts if isinstance(e, ast.Name)]
            for n in names:
                if is_dev:
                    tainted.add(n)
                else:
                    tainted.discard(n)  # rebound to a host value

    def _contains_device_value(self, expr: ast.AST, tainted: Set[str]) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in tainted:
                return True
            if isinstance(node, ast.Call) and _is_jnp_call(node):
                return True
        return False

    # -------------------------------------------------------------- the rules
    def _check_tracer_test(self, test: ast.AST, tainted: Set[str], where: str):
        if self._contains_device_value(test, tainted):
            self._add(
                "ANA001",
                test,
                f"{where} condition on a traced/device value -- a hidden "
                "host sync (or TracerBoolConversionError under jit); hoist "
                "to jnp.where / lax.cond, or fetch explicitly first",
            )

    def _check_jit_in_loop(self, loop: ast.stmt) -> None:
        for node in ast.walk(loop):
            if node is loop:
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Call) and _is_jit_expr(node):
                self._add(
                    "ANA004",
                    node,
                    "jax.jit called inside a loop body: every iteration "
                    "builds a fresh cache entry (silent retrace) -- hoist "
                    "the jit out of the loop",
                )

    def _check_defaults(self, fn: ast.AST, info: _FnInfo) -> None:
        args = getattr(fn, "args", None)
        if args is None:
            return
        defaults = list(args.defaults) + [
            d for d in args.kw_defaults if d is not None
        ]
        if info.jit:
            for d in defaults:
                if isinstance(d, (ast.Dict, ast.List, ast.Set)):
                    self._add(
                        "ANA004",
                        d,
                        f"mutable default argument on jitted function "
                        f"{getattr(fn, 'name', '<lambda>')!r}: unhashable "
                        "as a static and a retrace per fresh object",
                    )

    def _check_call(self, call: ast.Call, info: _FnInfo, tainted: Set[str]):
        d = _dotted(call.func) or ""
        leaf = d.rsplit(".", 1)[-1]

        # --- static_argnames over unhashable defaults (any context)
        if d in ("jax.jit", "jit"):
            self._check_static_argnames(call)

        # --- kernel-body rules
        if info.kernel:
            if d.startswith("np.") or d.startswith("numpy."):
                self._add(
                    "ANA003",
                    call,
                    f"{d}() inside a Pallas kernel body: operands are refs "
                    "in device memory; use jnp/pl ops on loaded blocks",
                )
            elif d in ("jnp.asarray", "jnp.array"):
                self._add(
                    "ANA003",
                    call,
                    f"{d}() inside a Pallas kernel body: kernel operands "
                    "are already arrays -- asarray implies host data",
                )
            elif d.startswith("jnp.") and leaf in DYNAMIC_SHAPE_CALLS:
                self._add(
                    "ANA003",
                    call,
                    f"{d}() has a value-dependent output shape -- it cannot "
                    "lower inside a kernel; use a masked fixed-shape form",
                )
            elif d in ("jax.device_put", "jax.device_get", "print"):
                self._add(
                    "ANA003",
                    call,
                    f"{d}() inside a Pallas kernel body is a host op; "
                    "use pl.debug_print / ref stores",
                )

        # --- in-jit host ops
        if info.jit and not info.kernel:
            if d in ("np.asarray", "np.array", "numpy.asarray", "numpy.array"):
                self._add(
                    "ANA002",
                    call,
                    f"{d}() under jit folds the operand to a trace-time "
                    "constant (or forces a transfer): use jnp, or move the "
                    "conversion outside the jitted function",
                )
            elif d in ("jax.device_get",):
                self._add(
                    "ANA002",
                    call,
                    "jax.device_get under jit is a transfer inside the "
                    "compiled program; return the value instead",
                )
            elif d == "print":
                self._add(
                    "ANA002",
                    call,
                    "print() under jit runs at trace time only; use "
                    "jax.debug.print for runtime values",
                )
            elif (
                isinstance(call.func, ast.Attribute)
                and call.func.attr in HOST_PULL_METHODS
            ):
                self._add(
                    "ANA002",
                    call,
                    f".{call.func.attr}() under jit syncs the device value "
                    "at trace time (ConcretizationTypeError on tracers)",
                )

        # --- implicit host pulls on device values (any context)
        pulled: Optional[ast.AST] = None
        if leaf in ("asarray", "array") and (
            d.startswith("np.") or d.startswith("numpy.")
        ):
            pulled = call.args[0] if call.args else None
        elif d in HOST_PULL_FUNCS and len(call.args) == 1:
            pulled = call.args[0]
        elif (
            isinstance(call.func, ast.Attribute)
            and call.func.attr in HOST_PULL_METHODS
        ):
            pulled = call.func.value
        if pulled is not None and self._is_device_expr(pulled, tainted):
            self._add(
                "ANA005",
                call,
                "implicit device->host pull of a traced/jitted result on "
                "the hot path; the sanctioned spelling is "
                "analysis.runtime.device_fetch (ANA006 budgets it)",
            )
        # --- explicit sync budget (hot-path dirs only)
        norm = "/" + self.path.replace(os.sep, "/")
        if (d == "jax.device_get" or leaf == "device_fetch") and any(
            seg in norm for seg in EXPLICIT_SYNC_DIRS
        ):
            self._add(
                "ANA006",
                call,
                f"explicit device->host fetch ({d}) on the hot path: "
                "planned sync points must be allowlisted with their budget "
                "(analysis/allowlist.txt, DESIGN.md §10)",
            )

    def _check_static_argnames(self, call: ast.Call) -> None:
        static: List[str] = []
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) and isinstance(
                        node.value, str
                    ):
                        static.append(node.value)
        if not static or not call.args:
            return
        target = call.args[0]
        fn = None
        if isinstance(target, ast.Name):
            for node in ast.walk(self.tree):
                if (
                    isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name == target.id
                ):
                    fn = node
                    break
        if fn is None:
            return
        args = fn.args
        named = args.args + args.kwonlyargs
        defaults = [None] * (len(args.args) - len(args.defaults)) + list(
            args.defaults
        ) + list(args.kw_defaults)
        for a, dflt in zip(named, defaults):
            if a.arg in static and isinstance(dflt, (ast.Dict, ast.List, ast.Set)):
                self._add(
                    "ANA004",
                    dflt,
                    f"static_argnames names {a.arg!r} whose default is "
                    "unhashable (dict/list/set): jit cache keys on statics "
                    "by hash -- this retraces or throws per call",
                )


# ---------------------------------------------------------------- allowlist
def load_allowlist(path: str) -> List[Tuple[str, str, str]]:
    """Parse ``<path-glob> <rule|*> <reason...>`` lines (# comments)."""
    entries: List[Tuple[str, str, str]] = []
    with open(path) as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split(None, 2)
            if len(parts) < 2:
                raise ValueError(f"malformed allowlist line: {raw!r}")
            glob, rule = parts[0], parts[1]
            reason = parts[2] if len(parts) > 2 else ""
            entries.append((glob, rule, reason))
    return entries


def is_allowlisted(
    v: Violation, entries: Sequence[Tuple[str, str, str]]
) -> bool:
    path = v.path.replace(os.sep, "/")
    for glob, rule, _reason in entries:
        if rule not in ("*", v.rule):
            continue
        if fnmatch.fnmatch(path, glob) or fnmatch.fnmatch(path, "*/" + glob):
            return True
    return False


DEFAULT_ALLOWLIST = os.path.join(os.path.dirname(__file__), "allowlist.txt")


def lint_paths(
    paths: Sequence[str], allowlist: Optional[str] = DEFAULT_ALLOWLIST
) -> Tuple[List[Violation], List[Violation]]:
    """Lint every .py under ``paths``; returns (violations, allowlisted)."""
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                files.extend(
                    os.path.join(root, n) for n in sorted(names)
                    if n.endswith(".py")
                )
    entries = load_allowlist(allowlist) if allowlist else []
    hard: List[Violation] = []
    soft: List[Violation] = []
    for path in sorted(set(files)):
        with open(path) as f:
            src = f.read()
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            hard.append(Violation("ANA000", path, e.lineno or 0, f"syntax error: {e.msg}"))
            continue
        seen: Set[Tuple[str, str, int, str]] = set()
        for v in Linter(os.path.relpath(path), tree).run():
            key = (v.rule, v.path, v.line, v.msg)
            if key in seen:
                continue  # nested-loop walks can visit a call twice
            seen.add(key)
            (soft if is_allowlisted(v, entries) else hard).append(v)
    return hard, soft
