"""repro.analysis: static contract checker + hot-path lint (DESIGN.md §10).

The correctness-tooling layer for everything under ``core/``, ``kernels/``
and ``serving/`` -- three cooperating passes behind one CLI
(``python -m repro.analysis`` / ``scripts/check_static.py``):

  * ``lint``       -- AST rules for the JAX footguns that cost this repo
                      throughput: tracer bool/if, host syncs on device
                      values, host ops inside Pallas kernel bodies,
                      retrace hazards (jit-in-loop, unhashable statics),
                      and an explicit-sync allowlist budget;
  * ``contracts``  -- shape/dtype/layout contracts on ``SearchPlan``, the
                      forest kernel operands, the delta quadruple and the
                      sharded program builders, verified abstractly via
                      ``jax.eval_shape`` on representative specs;
  * ``runtime``/``gate`` -- compile-cache instrumentation + transfer-guard
                      wiring asserting the steady-state ``BSTServer``
                      drain compiles nothing and moves nothing it did not
                      plan to move.

``invariants`` is the pure leaf both the checkers and the production code
import, so the scattered runtime asserts and the static checks share one
definition (DESIGN.md §10).  This ``__init__`` stays import-light on
purpose: ``core``/``serving`` import ``repro.analysis.invariants`` and
``repro.analysis.runtime``, and importing anything heavier here would
close the cycle.
"""
