"""Runtime retrace/transfer gate: steady-state serving is compile- and
transfer-free (DESIGN.md §10).

``serve_gate(strategy)`` drives a real ``BSTServer`` drain -- kernel path,
sharded through the strategy's serving mesh -- through a WARM phase (every
program the workload needs compiles exactly once: read programs via
``warmup``, the write-ingest program via one write drain) and then a
MEASURED phase under ``runtime.compile_watch()`` +
``runtime.transfer_watch()``:

  * >= ``n_chunks`` fixed-shape chunks drain per op, with small writes
    interleaved between read drains so the delta buffer's CONTENT changes
    while every shape stays constant -- the exact situation where a
    content-dependent-shape bug retraces;
  * zero compile records: every chunk replayed a cached program;
  * zero implicit transfers: the ``transfer_guard`` raises on any
    unplanned host->device movement, and the sanctioned ``device_fetch``
    count must equal the drain's exact retire budget (one fetch per read
    chunk -- ``BSTServer._fill_columns`` -- and nothing else);
  * zero compactions: the config pins ``delta_high_water`` to the
    capacity and writes far fewer entries, so the measured phase never
    pays the allowlisted one-sync-per-compaction.

Imports serving lazily so ``repro.analysis`` stays import-light for the
production modules that depend on ``invariants``/``runtime``.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.analysis import runtime
from repro.analysis.report import Violation

# Ops exercised by the gate: both point shapes, a range op (the lo||hi
# doubled-lane trace) -- each op is its own compiled program family.
GATE_OPS: Tuple[str, ...] = ("lookup", "predecessor", "range_scan")

_N_KEYS = 63
_CHUNK = 64
_DELTA_CAP = 64


def _violation(strategy: str, msg: str) -> Violation:
    return Violation("GAT001", f"serve-gate:{strategy}", 0, msg)


def serve_gate(
    strategy: str,
    *,
    n_chunks: int = 3,
    ops: Sequence[str] = GATE_OPS,
    n_trees: int = 4,
) -> List[Violation]:
    """Gate one strategy's steady-state drain; returns violations (empty =
    pass)."""
    from repro.core import distributed as dist_lib
    from repro.core.engine import EngineConfig
    from repro.serving.bst_server import BSTServer

    errors: List[Violation] = []
    keys = np.arange(1, _N_KEYS + 1, dtype=np.int32) * 3
    cfg = EngineConfig(
        strategy=strategy,
        n_trees=1 if strategy == "hrz" else n_trees,
        use_kernel=True,
        interpret=True,
        # High water == capacity and the measured writes stay far below it:
        # no compaction (and no sanctioned compaction sync) in the gate.
        delta_capacity=_DELTA_CAP,
        delta_high_water=_DELTA_CAP,
    )
    mesh = dist_lib.make_serving_mesh(strategy)
    srv = BSTServer(
        keys, keys * 7, cfg, chunk_size=_CHUNK, scan_k=4, mesh=mesh
    )

    # ---- warm phase: compile every program the measured phase replays.
    srv.warmup(tuple(ops))
    srv.submit_write(np.int32([keys[1], keys[3]]), np.int32([1, 3]))
    srv.drain()

    # ---- measured phase.
    compactions_before = srv.stats.compactions
    rng = np.random.default_rng(19120156)
    expected_fetches = 0
    with runtime.compile_watch() as cw, runtime.transfer_watch() as tw:
        for round_no in range(2):
            # Delta CONTENT changes between rounds; every shape constant.
            srv.submit_write(
                np.int32([keys[5 + round_no], keys[9 + round_no]]),
                np.int32([round_no, round_no + 1]),
            )
            srv.drain()
            for op in ops:
                B = n_chunks * _CHUNK
                q = rng.integers(0, keys[-1] + 2, size=B).astype(np.int32)
                if op in ("range_count", "range_scan"):
                    srv.submit_range(q, q + 17, op=op)
                else:
                    srv.submit(q, op=op)
                srv.drain()
                expected_fetches += n_chunks  # one device_fetch per chunk
    if cw.count:
        progs = "; ".join(cw.messages()[:4])
        errors.append(
            _violation(
                strategy,
                f"steady-state drain compiled {cw.count} program(s) -- "
                f"retrace detected: {progs}",
            )
        )
    if tw.fetches != expected_fetches:
        errors.append(
            _violation(
                strategy,
                f"{tw.fetches} sanctioned device fetches, budget is "
                f"{expected_fetches} (one per retired read chunk) -- an "
                "unplanned device->host sync crept onto the hot path",
            )
        )
    swept = srv.stats.compactions - compactions_before
    if swept:
        errors.append(
            _violation(
                strategy,
                f"{swept} compaction(s) fired in the measured phase -- the "
                "gate's write volume must stay below the high-water mark",
            )
        )
    return errors


def run_serve_gates(
    strategies: Sequence[str] = ("hrz", "dup", "hyb"), *, n_chunks: int = 3
) -> List[Violation]:
    errors: List[Violation] = []
    for strategy in strategies:
        errors.extend(serve_gate(strategy, n_chunks=n_chunks))
    return errors
