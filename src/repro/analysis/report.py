"""Violation records + the one exit-code/report helper every gate shares.

``scripts/check_bench.py`` and ``scripts/check_static.py`` (and the
``python -m repro.analysis`` CLI behind it) all finish through ``gate()``:
collect failures into a list, print what passed, and exit non-zero with
the full failure inventory -- never fail on the first finding, so one CI
run shows every violation.  ``write_json`` emits the machine-readable
report CI uploads as an artifact alongside the BENCH json.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding from any pass (lint / contracts / deadcode / gate)."""

    rule: str  # e.g. "ANA002"
    path: str  # repo-relative file path ("-" for non-file findings)
    line: int  # 1-based; 0 for whole-file/whole-run findings
    msg: str

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule}: {self.msg}"


def render_all(violations: Sequence[Violation]) -> str:
    return "\n".join(v.render() for v in violations)


def to_doc(
    violations: Sequence[Violation],
    allowlisted: Sequence[Violation] = (),
    extra: Optional[Dict] = None,
) -> Dict:
    """The static-report/v1 artifact document."""
    doc = {
        "schema": "static-report/v1",
        "violations": [dataclasses.asdict(v) for v in violations],
        "allowlisted": [dataclasses.asdict(v) for v in allowlisted],
    }
    if extra:
        doc.update(extra)
    return doc


def write_json(path: str, doc: Dict) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def gate(failures: Sequence[str], ok_msg: str) -> None:
    """The shared exit-code contract: print + return on success, raise
    ``SystemExit`` with the whole failure inventory otherwise."""
    if failures:
        lines = "\n".join(f"  {f}" for f in failures)
        raise SystemExit(f"{len(failures)} gate failure(s):\n{lines}")
    print(ok_msg)


def gate_violations(violations: Sequence[Violation], ok_msg: str) -> None:
    gate([v.render() for v in violations], ok_msg)
