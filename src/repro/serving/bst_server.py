"""BSTServer: streaming request scheduler over immutable tree snapshots.

The paper's deployment story (DESIGN.md §5): search streams are served at
full throughput from an immutable snapshot while inserts/deletes accumulate;
a bulk update builds a fresh perfect tree and the server swaps snapshots
atomically between chunks.  This module is that loop, TPU-native:

  * **typed request kinds** -- every query op of DESIGN.md §6 is a request
    kind: ``lookup`` / ``predecessor`` / ``successor`` via ``submit``,
    ``range_count`` / ``range_scan`` via ``submit_range``.  The drain packs
    each kind into its own fixed-shape chunk stream (one jit shape per op),
    and stats are accounted per op;
  * **chunk accumulation** -- requests of any size are queued and packed
    into fixed ``chunk_size`` engine calls (the jit shape), padding only the
    final partial chunk per op; per-request results are sliced back out, so
    padded lanes never leak into answers or accounting;
  * **pluggable engine config** -- any ``EngineConfig`` (strategy, mapping,
    kernel/reference path) serves the same request API;
  * **snapshot swap** -- ``apply_updates`` runs ``core.updates`` bulk
    insert/delete on the current snapshot and installs a new engine; lookups
    submitted before the swap but not yet drained see the new snapshot
    (drain-before-swap if read-your-epoch consistency is required);
  * **keys/sec accounting** -- per-chunk timing with ``block_until_ready``,
    found counts accumulated per chunk (not just the final one).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core import updates as updates_lib
from repro.core.engine import BSTEngine, EngineConfig
from repro.core.tree import TreeData

# Derived from the plans-layer contract so a new op cannot drift past the
# server's request typing.
RANGE_OPS = plans_lib.RANGE_OPS
POINT_OPS = tuple(op for op in plans_lib.QUERY_OPS if op not in RANGE_OPS)


@dataclasses.dataclass
class OpStats:
    """Per-op serving counters (one entry per request kind actually seen)."""

    served: int = 0  # keys (point ops) / ranges (range ops) answered
    chunks: int = 0  # engine invocations
    busy_s: float = 0.0  # time inside the engine (incl. padding lanes)

    @property
    def keys_per_sec(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0


@dataclasses.dataclass
class ServerStats:
    """Cumulative serving counters (reset with ``BSTServer.reset_stats``)."""

    requests: int = 0  # submit() calls
    submitted: int = 0  # keys/ranges accepted
    served: int = 0  # keys/ranges answered
    found: int = 0  # lookup hits, accumulated per chunk
    chunks: int = 0  # engine invocations
    busy_s: float = 0.0  # time inside the engine (incl. padding lanes)
    snapshot_swaps: int = 0
    per_op: Dict[str, OpStats] = dataclasses.field(default_factory=dict)

    @property
    def keys_per_sec(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0

    def op(self, name: str) -> OpStats:
        return self.per_op.setdefault(name, OpStats())


@dataclasses.dataclass
class _Request:
    ticket: int
    op: str
    a: np.ndarray  # keys (point ops) / range lows
    b: Optional[np.ndarray]  # range highs (range ops only)


class BSTServer:
    """Accumulate typed query requests, serve them in fixed-shape chunks.

    Single-threaded by design: the FPGA frontend is one stream of key
    chunks, and on TPU one jit shape per op amortises compilation.
    Thread-safety is the caller's concern (wrap submit/drain in a lock if
    shared).  ``scan_k`` fixes range_scan's bounded fan-out (part of the jit
    shape, so it is a server-level constant).
    """

    def __init__(
        self,
        keys,
        values,
        config: EngineConfig = EngineConfig(),
        chunk_size: int = 8192,
        scan_k: int = 8,
    ):
        if chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        if scan_k < 1:
            raise ValueError("scan_k must be positive")
        self.config = config
        self.chunk_size = chunk_size
        self.scan_k = scan_k
        self.stats = ServerStats()
        self._pending: List[_Request] = []
        self._pending_keys = 0
        self._next_ticket = 0
        self._warm_ops: Tuple[str, ...] = ()
        self._install(tree_lib.build_tree(np.asarray(keys), np.asarray(values)))

    # --------------------------------------------------------------- snapshot
    def _install(self, tree: TreeData) -> None:
        self._tree = tree
        self._engine = BSTEngine.from_tree(tree, self.config)
        if self._warm_ops:
            # The fresh engine's jit closes over the new snapshot; re-warm so
            # post-swap chunks (and keys/sec accounting) stay compile-free.
            self.warmup(self._warm_ops)

    @property
    def snapshot(self) -> TreeData:
        """The current immutable tree snapshot."""
        return self._tree

    @property
    def engine(self) -> BSTEngine:
        return self._engine

    def warmup(self, ops: Tuple[str, ...] = ("lookup",)) -> None:
        """Populate the jit cache so timing excludes compilation.

        Pass the ops the workload will use; once called, every snapshot swap
        re-warms the same set on the fresh engine too.
        """
        dummy = np.zeros(self.chunk_size, np.int32)
        for op in ops:
            if op in RANGE_OPS:
                out = self._engine.query(op, dummy, dummy, k=self.scan_k)
            else:
                out = self._engine.query(op, dummy)
            jax.block_until_ready(out)
        self._warm_ops = tuple(dict.fromkeys(self._warm_ops + tuple(ops)))

    def apply_updates(
        self,
        insert_keys=None,
        insert_values=None,
        delete_keys=None,
    ) -> TreeData:
        """Bulk-maintain the store and swap in the fresh snapshot.

        Deletes are applied before inserts, so an upsert of a just-deleted
        key lands.  Returns the new snapshot.  Pending (undrained) requests
        will be served from the new snapshot.
        """
        tree = self._tree
        if delete_keys is not None and len(np.atleast_1d(delete_keys)):
            tree = updates_lib.bulk_delete(tree, delete_keys)
        if insert_keys is not None and len(np.atleast_1d(insert_keys)):
            if insert_values is None:
                raise ValueError("insert_keys needs insert_values")
            tree = updates_lib.bulk_insert(tree, insert_keys, insert_values)
        self._install(tree)
        self.stats.snapshot_swaps += 1
        return tree

    # --------------------------------------------------------------- requests
    def submit(self, request_keys, op: str = "lookup") -> int:
        """Queue a point-query request; returns a ticket for drain().

        ``op`` is one of ``lookup`` (values, found), ``predecessor`` /
        ``successor`` (keys, values, ok) -- DESIGN.md §6 semantics.
        """
        if op not in POINT_OPS:
            raise ValueError(f"submit() op must be one of {POINT_OPS}, got {op!r}")
        req = np.atleast_1d(np.asarray(request_keys, np.int32))
        if req.ndim != 1:
            raise ValueError("request_keys must be scalar or 1-D")
        return self._enqueue(_Request(0, op, req, None), req.size)

    def submit_range(self, lo, hi, op: str = "range_count") -> int:
        """Queue a range request over [lo, hi] (inclusive); returns a ticket.

        ``op`` is ``range_count`` (counts) or ``range_scan`` (keys (B,
        scan_k), values, counts).  lo/hi must be equal-length (or scalar).
        """
        if op not in RANGE_OPS:
            raise ValueError(f"submit_range() op must be one of {RANGE_OPS}, got {op!r}")
        lo = np.atleast_1d(np.asarray(lo, np.int32))
        hi = np.atleast_1d(np.asarray(hi, np.int32))
        if lo.shape != hi.shape or lo.ndim != 1:
            raise ValueError("lo/hi must be equal-length scalars or 1-D arrays")
        return self._enqueue(_Request(0, op, lo, hi), lo.size)

    def _enqueue(self, req: _Request, size: int) -> int:
        req.ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append(req)
        self._pending_keys += size
        self.stats.requests += 1
        self.stats.submitted += size
        return req.ticket

    def pending(self) -> int:
        """Keys/ranges queued but not yet served."""
        return self._pending_keys

    # ------------------------------------------------------------------ drain
    def drain(self) -> Dict[int, tuple]:
        """Serve every queued request; returns {ticket: op results}.

        Result shapes per op: ``lookup`` -> (values, found);
        ``predecessor``/``successor`` -> (keys, values, ok);
        ``range_count`` -> (counts,); ``range_scan`` -> (keys, values,
        counts).  Each op's stream is packed into its own ``chunk_size``
        engine calls; only the final partial chunk per op is padded, and
        padded lanes are dropped before results or accounting.
        """
        if not self._pending:
            return {}
        batch = self._pending
        self._pending = []
        self._pending_keys = 0

        by_op: Dict[str, List[_Request]] = {}
        for req in batch:
            by_op.setdefault(req.op, []).append(req)

        out: Dict[int, tuple] = {}
        for op, reqs in by_op.items():
            a = np.concatenate([r.a for r in reqs])
            b = np.concatenate([r.b for r in reqs]) if op in RANGE_OPS else None
            columns = self._serve_stream(op, a, b)
            lo = 0
            for r in reqs:
                hi = lo + r.a.size
                out[r.ticket] = tuple(col[lo:hi] for col in columns)
                lo = hi
        return out

    def _empty_columns(self, op: str):
        """Result columns for a zero-key stream (no engine call needed)."""
        if op == "lookup":
            return [np.empty(0, np.int32), np.empty(0, bool)]
        if op in ("predecessor", "successor"):
            return [np.empty(0, np.int32), np.empty(0, np.int32), np.empty(0, bool)]
        if op == "range_count":
            return [np.empty(0, np.int32)]
        k = self.scan_k
        return [
            np.empty((0, k), np.int32),
            np.empty((0, k), np.int32),
            np.empty(0, np.int32),
        ]

    def _serve_stream(self, op: str, a: np.ndarray, b: Optional[np.ndarray]):
        """Run one op's packed stream through fixed-shape engine chunks."""
        B = a.size
        if B == 0:
            return self._empty_columns(op)
        pad = (-B) % self.chunk_size
        if pad:
            a = np.pad(a, (0, pad))
            if b is not None:
                b = np.pad(b, (0, pad))
        columns = None
        for lo in range(0, a.size, self.chunk_size):
            sl = slice(lo, lo + self.chunk_size)
            t0 = time.perf_counter()
            if op in RANGE_OPS:
                res = self._engine.query(op, a[sl], b[sl], k=self.scan_k)
            else:
                res = self._engine.query(op, a[sl])
            if not isinstance(res, tuple):
                res = (res,)
            jax.block_until_ready(res)
            dt = time.perf_counter() - t0
            self.stats.busy_s += dt
            self.stats.chunks += 1
            ops = self.stats.op(op)
            ops.busy_s += dt
            ops.chunks += 1
            if columns is None:
                columns = [
                    np.empty((a.size,) + np.asarray(c).shape[1:], np.asarray(c).dtype)
                    for c in res
                ]
            for col, c in zip(columns, res):
                col[sl] = np.asarray(c)
            if op == "lookup":
                # hits accumulated per chunk, padded lanes excluded below
                real = min(self.chunk_size, B - lo)
                self.stats.found += int(np.asarray(res[1])[:real].sum())
        self.stats.served += B
        self.stats.op(op).served += B
        return [col[:B] for col in columns]

    # ------------------------------------------------------------ convenience
    def lookup(self, request_keys) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: submit one request and drain the queue."""
        ticket = self.submit(request_keys)
        return self.drain()[ticket]

    def predecessor(self, request_keys):
        ticket = self.submit(request_keys, op="predecessor")
        return self.drain()[ticket]

    def successor(self, request_keys):
        ticket = self.submit(request_keys, op="successor")
        return self.drain()[ticket]

    def range_count(self, lo, hi) -> np.ndarray:
        ticket = self.submit_range(lo, hi, op="range_count")
        return self.drain()[ticket][0]

    def range_scan(self, lo, hi):
        ticket = self.submit_range(lo, hi, op="range_scan")
        return self.drain()[ticket]

    # ------------------------------------------------------------- accounting
    def reset_stats(self) -> None:
        self.stats = ServerStats()

    def memory_nodes(self) -> int:
        return self._engine.memory_nodes()
