"""BSTServer: streaming request scheduler over immutable tree snapshots.

The paper's deployment story (DESIGN.md §5): search streams are served at
full throughput from an immutable snapshot while inserts/deletes accumulate;
a bulk update builds a fresh perfect tree and the server swaps snapshots
atomically between chunks.  This module is that loop, TPU-native:

  * **chunk accumulation** -- requests of any size are queued and packed
    into fixed ``chunk_size`` engine calls (the jit shape), padding only the
    final partial chunk; per-request results are sliced back out, so padded
    lanes never leak into answers or accounting;
  * **pluggable engine config** -- any ``EngineConfig`` (strategy, mapping,
    kernel/reference path) serves the same request API;
  * **snapshot swap** -- ``apply_updates`` runs ``core.updates`` bulk
    insert/delete on the current snapshot and installs a new engine; lookups
    submitted before the swap but not yet drained see the new snapshot
    (drain-before-swap if read-your-epoch consistency is required);
  * **keys/sec accounting** -- per-chunk timing with ``block_until_ready``,
    found counts accumulated per chunk (not just the final one).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import tree as tree_lib
from repro.core import updates as updates_lib
from repro.core.engine import BSTEngine, EngineConfig
from repro.core.tree import TreeData


@dataclasses.dataclass
class ServerStats:
    """Cumulative serving counters (reset with ``BSTServer.reset_stats``)."""

    requests: int = 0  # submit() calls
    submitted: int = 0  # keys accepted
    served: int = 0  # keys answered
    found: int = 0  # hits, accumulated per chunk
    chunks: int = 0  # engine invocations
    busy_s: float = 0.0  # time inside the engine (incl. padding lanes)
    snapshot_swaps: int = 0

    @property
    def keys_per_sec(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0


class BSTServer:
    """Accumulate lookup requests, serve them in fixed-shape chunks.

    Single-threaded by design: the FPGA frontend is one stream of key
    chunks, and on TPU one jit shape amortises compilation.  Thread-safety
    is the caller's concern (wrap submit/drain in a lock if shared).
    """

    def __init__(
        self,
        keys,
        values,
        config: EngineConfig = EngineConfig(),
        chunk_size: int = 8192,
    ):
        if chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        self.config = config
        self.chunk_size = chunk_size
        self.stats = ServerStats()
        self._pending: List[Tuple[int, np.ndarray]] = []
        self._pending_keys = 0
        self._next_ticket = 0
        self._warmed = False
        self._install(tree_lib.build_tree(np.asarray(keys), np.asarray(values)))

    # --------------------------------------------------------------- snapshot
    def _install(self, tree: TreeData) -> None:
        self._tree = tree
        self._engine = BSTEngine.from_tree(tree, self.config)
        if self._warmed:
            # The fresh engine's jit closes over the new snapshot; re-warm so
            # post-swap chunks (and keys/sec accounting) stay compile-free.
            self.warmup()

    @property
    def snapshot(self) -> TreeData:
        """The current immutable tree snapshot."""
        return self._tree

    @property
    def engine(self) -> BSTEngine:
        return self._engine

    def warmup(self) -> None:
        """Populate the jit cache so timing excludes compilation.

        Once called, every snapshot swap re-warms the fresh engine too.
        """
        dummy = np.zeros(self.chunk_size, np.int32)
        jax.block_until_ready(self._engine.lookup(dummy))
        self._warmed = True

    def apply_updates(
        self,
        insert_keys=None,
        insert_values=None,
        delete_keys=None,
    ) -> TreeData:
        """Bulk-maintain the store and swap in the fresh snapshot.

        Deletes are applied before inserts, so an upsert of a just-deleted
        key lands.  Returns the new snapshot.  Pending (undrained) requests
        will be served from the new snapshot.
        """
        tree = self._tree
        if delete_keys is not None and len(np.atleast_1d(delete_keys)):
            tree = updates_lib.bulk_delete(tree, delete_keys)
        if insert_keys is not None and len(np.atleast_1d(insert_keys)):
            if insert_values is None:
                raise ValueError("insert_keys needs insert_values")
            tree = updates_lib.bulk_insert(tree, insert_keys, insert_values)
        self._install(tree)
        self.stats.snapshot_swaps += 1
        return tree

    # --------------------------------------------------------------- requests
    def submit(self, request_keys) -> int:
        """Queue a lookup request; returns a ticket redeemable at drain()."""
        req = np.atleast_1d(np.asarray(request_keys, np.int32))
        if req.ndim != 1:
            raise ValueError("request_keys must be scalar or 1-D")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, req))
        self._pending_keys += req.size
        self.stats.requests += 1
        self.stats.submitted += req.size
        return ticket

    def pending(self) -> int:
        """Keys queued but not yet served."""
        return self._pending_keys

    def drain(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        """Serve every queued request; returns {ticket: (values, found)}.

        The queue is packed into ``chunk_size`` engine calls; only the final
        partial chunk is padded, and padded lanes are dropped before results
        or accounting.
        """
        if not self._pending:
            return {}
        batch = list(self._pending)
        self._pending = []
        self._pending_keys = 0

        stream = np.concatenate([req for _, req in batch])
        B = stream.size
        pad = (-B) % self.chunk_size
        if pad:
            stream = np.pad(stream, (0, pad))
        vals = np.empty(stream.size, np.int32)
        found = np.empty(stream.size, bool)
        for lo in range(0, stream.size, self.chunk_size):
            t0 = time.perf_counter()
            v, f = self._engine.lookup(stream[lo : lo + self.chunk_size])
            jax.block_until_ready((v, f))
            self.stats.busy_s += time.perf_counter() - t0
            self.stats.chunks += 1
            vals[lo : lo + self.chunk_size] = np.asarray(v)
            found[lo : lo + self.chunk_size] = np.asarray(f)

        self.stats.served += B
        self.stats.found += int(found[:B].sum())  # per chunk-run, real lanes only
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        lo = 0
        for ticket, req in batch:
            hi = lo + req.size
            out[ticket] = (vals[lo:hi], found[lo:hi])
            lo = hi
        return out

    def lookup(self, request_keys) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: submit one request and drain the queue."""
        ticket = self.submit(request_keys)
        return self.drain()[ticket]

    # ------------------------------------------------------------- accounting
    def reset_stats(self) -> None:
        self.stats = ServerStats()

    def memory_nodes(self) -> int:
        return self._engine.memory_nodes()
