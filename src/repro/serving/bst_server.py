"""BSTServer: streaming request scheduler over immutable tree snapshots.

The paper's deployment story (DESIGN.md §5): search streams are served at
full throughput from an immutable snapshot while inserts/deletes accumulate;
a bulk update builds a fresh perfect tree and the server swaps snapshots
atomically between chunks.  This module is that loop, TPU-native:

  * **typed request kinds** -- every query op of DESIGN.md §6 is a request
    kind: ``lookup`` / ``predecessor`` / ``successor`` via ``submit``,
    ``range_count`` / ``range_scan`` via ``submit_range``.  The drain packs
    each kind into its own fixed-shape chunk stream (one jit shape per op),
    and stats are accounted per op;
  * **chunk accumulation** -- requests of any size are queued and packed
    into fixed ``chunk_size`` engine calls (the jit shape), padding only the
    final partial chunk per op; per-request results are sliced back out, so
    padded lanes never leak into answers or accounting;
  * **pluggable engine config** -- any ``EngineConfig`` (strategy, mapping,
    kernel/reference path) serves the same request API;
  * **live write path** (DESIGN.md §7) -- with
    ``EngineConfig(delta_capacity > 0)`` the server also takes ``write`` /
    ``delete`` request kinds (``submit_write`` / ``submit_delete``).  The
    drain preserves SUBMISSION ORDER across read/write boundaries: requests
    split into maximal read spans (order-independent, packed per op exactly
    as before) separated by write spans, each write span lands in the
    engine's device-side delta buffer as fixed-shape padded chunks, and
    compaction -- the engine's bulk merge into a fresh snapshot -- triggers
    between chunks at the high-water mark instead of a full O(n + m)
    rebuild per update.  Per-op stats cover writes too, plus cumulative
    ``updates`` and ``compactions`` counters;
  * **snapshot swap** -- ``apply_updates`` on a write-path engine routes
    through the delta buffer (above); otherwise it runs ``core.updates``
    bulk insert/delete and installs a new engine.  Lookups submitted before
    the swap but not yet drained see the new state (drain-before-swap if
    read-your-epoch consistency is required);
  * **keys/sec accounting** -- per-chunk timing with ``block_until_ready``,
    found counts accumulated per chunk (not just the final one).  Busy
    seconds are attributed per op by the engine lanes each request
    actually occupied (one per point/write/delete key, two per range
    request -- the lo||hi concatenated descent), so mixed spans cannot
    skew one op's ``keys_per_sec`` with another op's time;
    ``lanes_per_sec`` is the figure comparable across op mixes;
  * **sharded mode** (DESIGN.md §9) -- construct with ``mesh=`` and every
    read chunk routes through the strategy's shard_map-lowered plan
    (``core.distributed.make_sharded_query``: hrz shards the tree by
    subtree behind the all_to_all router, dup replicates the tree and
    splits the chunk, hyb shards the vertical forest and replicates the
    register layer).  Chunks are served by an async DOUBLE-BUFFERED
    scheduler: the next fixed-shape chunk is formed and dispatched while
    the previous one is still in flight, and the sync point trails one
    chunk behind, so host-side packing overlaps device compute.  The
    write path is unchanged -- ingest classifies against the local
    snapshot, and the pending buffer rides every sharded read as four
    REPLICATED operands folded on-device inside the sharded program; a
    compaction rebuilds the sharded programs via the engine's
    ``on_snapshot`` hook before the next read.  ``chunk_size`` must
    divide by the mesh axis size (chunks are always padded full, so no
    unpadded partial chunk can ever reach a sharded program).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis import invariants
from repro.analysis import runtime as analysis_runtime
from repro.core import distributed as dist_lib
from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core import updates as updates_lib
from repro.core.engine import BSTEngine, EngineConfig
from repro.core.tree import TreeData

# Derived from the plans-layer contract so a new op cannot drift past the
# server's request typing.
RANGE_OPS = plans_lib.RANGE_OPS
POINT_OPS = tuple(op for op in plans_lib.QUERY_OPS if op not in RANGE_OPS)
# Mutating request kinds (DESIGN.md §7); these are drain-order barriers.
WRITE_OPS = ("write", "delete")


@dataclasses.dataclass
class OpStats:
    """Per-op serving counters (one entry per request kind actually seen)."""

    served: int = 0  # keys (point ops) / ranges (range ops) answered
    chunks: int = 0  # engine invocations
    busy_s: float = 0.0  # time inside the engine (incl. padding lanes)
    # Engine lanes the op's requests actually occupied (padding excluded):
    # one per key for point/write/delete ops, TWO per range request -- the
    # lo and hi bounds both descend (the lo||hi concatenated pass,
    # DESIGN.md §6).  Busy seconds in shared spans are attributed by this
    # number, and lanes_per_sec is the throughput figure comparable across
    # op mixes (keys_per_sec counts range requests as one unit each).
    lanes: int = 0

    @property
    def keys_per_sec(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def lanes_per_sec(self) -> float:
        return self.lanes / self.busy_s if self.busy_s > 0 else 0.0


@dataclasses.dataclass
class ServerStats:
    """Cumulative serving counters (reset with ``BSTServer.reset_stats``)."""

    requests: int = 0  # submit() calls
    submitted: int = 0  # keys/ranges accepted
    served: int = 0  # keys/ranges/write-ops answered
    found: int = 0  # lookup hits, accumulated per chunk
    chunks: int = 0  # engine invocations
    busy_s: float = 0.0  # time inside the engine (incl. padding lanes)
    lanes: int = 0  # engine lanes occupied (see OpStats.lanes)
    snapshot_swaps: int = 0  # full-rebuild swaps (the non-delta path)
    updates: int = 0  # write/delete ops absorbed by the delta buffer
    compactions: int = 0  # delta-buffer merges into fresh snapshots
    per_op: Dict[str, OpStats] = dataclasses.field(default_factory=dict)

    @property
    def keys_per_sec(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def lanes_per_sec(self) -> float:
        return self.lanes / self.busy_s if self.busy_s > 0 else 0.0

    def op(self, name: str) -> OpStats:
        return self.per_op.setdefault(name, OpStats())


@dataclasses.dataclass
class _Request:
    ticket: int
    op: str
    a: np.ndarray  # keys (point / write / delete ops) / range lows
    b: Optional[np.ndarray]  # range highs (range ops) / write values


class BSTServer:
    """Accumulate typed query requests, serve them in fixed-shape chunks.

    Single-threaded by design: the FPGA frontend is one stream of key
    chunks, and on TPU one jit shape per op amortises compilation.
    Thread-safety is the caller's concern (wrap submit/drain in a lock if
    shared).  ``scan_k`` fixes range_scan's bounded fan-out (part of the jit
    shape, so it is a server-level constant).
    """

    def __init__(
        self,
        keys,
        values,
        config: EngineConfig = EngineConfig(),
        chunk_size: int = 8192,
        scan_k: int = 8,
        mesh=None,
    ):
        if chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        if scan_k < 1:
            raise ValueError("scan_k must be positive")
        self.config = config
        self.chunk_size = chunk_size
        self.scan_k = scan_k
        self.mesh = mesh
        self._squery = None
        if mesh is not None:
            axis = plans_lib.mesh_axis_for_strategy(config.strategy)
            if axis not in mesh.axis_names:
                raise ValueError(
                    f"strategy {config.strategy!r} shards over axis {axis!r}; "
                    f"the mesh has {mesh.axis_names} (see "
                    "distributed.make_serving_mesh)"
                )
            # Shared with repro.analysis.contracts: the checker verifies the
            # same bound statically, so neither side can drift (DESIGN.md §10).
            invariants.check_chunk_divides(chunk_size, mesh.shape[axis], axis)
        self.stats = ServerStats()
        self._pending: List[_Request] = []
        self._pending_keys = 0
        self._next_ticket = 0
        self._warm_ops: Tuple[str, ...] = ()
        # Fixed jit shape for delta-buffer write chunks (DESIGN.md §7): one
        # compiled ingest program regardless of request sizes.
        self._write_chunk = (
            min(chunk_size, config.delta_capacity)
            if config.delta_capacity > 0
            else chunk_size
        )
        self._install(tree_lib.build_tree(np.asarray(keys), np.asarray(values)))

    # --------------------------------------------------------------- snapshot
    def _install(self, tree: TreeData) -> None:
        self._engine = BSTEngine.from_tree(tree, self.config)
        if self.mesh is not None:
            self._install_sharded(tree)
            # Compaction can swap the snapshot deep inside apply_ops'
            # chunk loop; the hook rebuilds the sharded programs before
            # any later read can see the stale tree (DESIGN.md §9).
            self._engine.on_snapshot = self._install_sharded
        if self._warm_ops:
            # The fresh engine's jit closes over the new snapshot; re-warm so
            # post-swap chunks (and keys/sec accounting) stay compile-free.
            self.warmup(self._warm_ops)

    def _install_sharded(self, tree: TreeData) -> None:
        cfg = self.config
        self._squery = dist_lib.make_sharded_query(
            tree,
            self.mesh,
            cfg.strategy,
            buffer_slack=cfg.buffer_slack,
            use_kernel=cfg.use_kernel,
            interpret=cfg.interpret,
        )

    @property
    def snapshot(self) -> TreeData:
        """The current immutable tree snapshot (pending delta-buffer writes,
        if any, overlay it until the next compaction)."""
        return self._engine.tree

    @property
    def engine(self) -> BSTEngine:
        return self._engine

    def warmup(self, ops: Tuple[str, ...] = ("lookup",)) -> None:
        """Populate the jit cache so timing excludes compilation.

        Pass the ops the workload will use; once called, every snapshot swap
        re-warms the same set on the fresh engine too.
        """
        dummy = np.zeros(self.chunk_size, np.int32)
        for op in ops:
            out = self._query_chunk(op, dummy, dummy)
            jax.block_until_ready(out)
        self._warm_ops = tuple(dict.fromkeys(self._warm_ops + tuple(ops)))

    def _query_chunk(self, op: str, a, b) -> tuple:
        """One fixed-shape chunk through the serving datapath: the sharded
        shard_map program when a mesh is installed, the local engine
        otherwise.  The pending delta buffer rides sharded reads as
        replicated operands (on-device fold, DESIGN.md §9); the engine
        threads its own buffer internally."""
        if self._squery is not None:
            kw = {"delta": self._engine.delta} if self._engine.delta is not None else {}
            if op in RANGE_OPS:
                res = self._squery(op, a, b, k=self.scan_k, **kw)
            else:
                res = self._squery(op, a, **kw)
        elif op in RANGE_OPS:
            res = self._engine.query(op, a, b, k=self.scan_k)
        else:
            res = self._engine.query(op, a)
        return res if isinstance(res, tuple) else (res,)

    def apply_updates(
        self,
        insert_keys=None,
        insert_values=None,
        delete_keys=None,
    ) -> TreeData:
        """Bulk-maintain the store (deletes before inserts, so an upsert of
        a just-deleted key lands).  Returns the current snapshot.  Pending
        (undrained) requests will be served from the new state.

        With the write path enabled (``delta_capacity > 0``) the batch is
        absorbed by the engine's device-side delta buffer -- no rebuild,
        compaction at the high-water mark (DESIGN.md §7).  Otherwise this
        is the legacy full rebuild + snapshot swap.
        """
        n_ops = sum(
            len(np.atleast_1d(x)) for x in (insert_keys, delete_keys)
            if x is not None
        )
        if self._engine.delta is not None:
            before = self._engine.compactions
            self._engine.apply_updates(insert_keys, insert_values, delete_keys)
            self.stats.updates += n_ops
            self.stats.compactions += self._engine.compactions - before
            if self._engine.compactions != before and self._warm_ops:
                self.warmup(self._warm_ops)  # compaction reset the jit cache
            return self._engine.tree
        tree = self._engine.tree
        if delete_keys is not None and len(np.atleast_1d(delete_keys)):
            tree = updates_lib.bulk_delete(tree, delete_keys)
        if insert_keys is not None and len(np.atleast_1d(insert_keys)):
            if insert_values is None:
                raise ValueError("insert_keys needs insert_values")
            tree = updates_lib.bulk_insert(tree, insert_keys, insert_values)
        self._install(tree)
        self.stats.snapshot_swaps += 1
        return tree

    # --------------------------------------------------------------- requests
    def submit(self, request_keys, op: str = "lookup") -> int:
        """Queue a point-query request; returns a ticket for drain().

        ``op`` is one of ``lookup`` (values, found), ``predecessor`` /
        ``successor`` (keys, values, ok) -- DESIGN.md §6 semantics.
        """
        if op not in POINT_OPS:
            raise ValueError(f"submit() op must be one of {POINT_OPS}, got {op!r}")
        req = np.atleast_1d(np.asarray(request_keys, np.int32))
        if req.ndim != 1:
            raise ValueError("request_keys must be scalar or 1-D")
        return self._enqueue(_Request(0, op, req, None), req.size)

    def submit_range(self, lo, hi, op: str = "range_count") -> int:
        """Queue a range request over [lo, hi] (inclusive); returns a ticket.

        ``op`` is ``range_count`` (counts) or ``range_scan`` (keys (B,
        scan_k), values, counts).  lo/hi must be equal-length (or scalar).
        """
        if op not in RANGE_OPS:
            raise ValueError(f"submit_range() op must be one of {RANGE_OPS}, got {op!r}")
        lo = np.atleast_1d(np.asarray(lo, np.int32))
        hi = np.atleast_1d(np.asarray(hi, np.int32))
        if lo.shape != hi.shape or lo.ndim != 1:
            raise ValueError("lo/hi must be equal-length scalars or 1-D arrays")
        return self._enqueue(_Request(0, op, lo, hi), lo.size)

    def submit_write(self, request_keys, request_values) -> int:
        """Queue an upsert request (DESIGN.md §7); returns a ticket.

        Requires a write-path engine (``delta_capacity > 0``).  The drain
        applies writes in SUBMISSION ORDER relative to every other request
        (reads before the write see the old state, reads after see it);
        the ticket resolves to ``(applied_count,)``.
        """
        self._require_write_path()
        k = np.atleast_1d(np.asarray(request_keys, np.int32))
        v = np.atleast_1d(np.asarray(request_values, np.int32))
        if k.shape != v.shape or k.ndim != 1:
            raise ValueError("keys/values must be equal-length scalars or 1-D")
        return self._enqueue(_Request(0, "write", k, v), k.size)

    def submit_delete(self, request_keys) -> int:
        """Queue a delete (tombstone) request; returns a ticket.

        Same ordering contract as ``submit_write``; deleting an absent key
        is a no-op that still counts as applied.
        """
        self._require_write_path()
        k = np.atleast_1d(np.asarray(request_keys, np.int32))
        if k.ndim != 1:
            raise ValueError("request_keys must be scalar or 1-D")
        return self._enqueue(_Request(0, "delete", k, None), k.size)

    def _require_write_path(self) -> None:
        if self._engine.delta is None:
            raise ValueError(
                "write/delete request kinds need EngineConfig(delta_capacity"
                " > 0); use apply_updates() for bulk snapshot swaps"
            )

    def _enqueue(self, req: _Request, size: int) -> int:
        req.ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append(req)
        self._pending_keys += size
        self.stats.requests += 1
        self.stats.submitted += size
        return req.ticket

    def pending(self) -> int:
        """Keys/ranges queued but not yet served."""
        return self._pending_keys

    # ------------------------------------------------------------------ drain
    def drain(self) -> Dict[int, tuple]:
        """Serve every queued request; returns {ticket: op results}.

        Result shapes per op: ``lookup`` -> (values, found);
        ``predecessor``/``successor`` -> (keys, values, ok);
        ``range_count`` -> (counts,); ``range_scan`` -> (keys, values,
        counts); ``write``/``delete`` -> (applied_count,).

        Write requests are ORDER BARRIERS: the queue splits into maximal
        read spans separated by write spans, served in submission order, so
        a read observes exactly the writes submitted before it.  Within a
        read span (reads commute) each op's stream is packed into its own
        ``chunk_size`` engine calls exactly as before; write spans land in
        the delta buffer as fixed-shape padded chunks (DESIGN.md §7), with
        compaction between chunks when the high-water mark trips.  Only
        final partial chunks are padded, and padded lanes never reach
        results or accounting.
        """
        if not self._pending:
            return {}
        batch = self._pending
        self._pending = []
        self._pending_keys = 0

        out: Dict[int, tuple] = {}
        span: List[_Request] = []
        for req in batch:
            if req.op in WRITE_OPS:
                if span and span[-1].op not in WRITE_OPS:
                    self._serve_read_span(span, out)
                    span = []
            elif span and span[-1].op in WRITE_OPS:
                self._serve_write_span(span, out)
                span = []
            span.append(req)
        if span:
            if span[-1].op in WRITE_OPS:
                self._serve_write_span(span, out)
            else:
                self._serve_read_span(span, out)
        return out

    def _serve_read_span(self, reqs: List[_Request], out: Dict[int, tuple]):
        """One writeless span: requests commute, so pack per op kind."""
        by_op: Dict[str, List[_Request]] = {}
        for req in reqs:
            by_op.setdefault(req.op, []).append(req)
        for op, group in by_op.items():
            a = np.concatenate([r.a for r in group])
            b = np.concatenate([r.b for r in group]) if op in RANGE_OPS else None
            columns = self._serve_stream(op, a, b)
            lo = 0
            for r in group:
                hi = lo + r.a.size
                out[r.ticket] = tuple(col[lo:hi] for col in columns)
                lo = hi

    def _serve_write_span(self, reqs: List[_Request], out: Dict[int, tuple]):
        """One run of consecutive write/delete requests -> delta ingest.

        Consecutive mutations merge into a single submission-ordered batch
        (the buffer's last-wins dedup preserves exactly that order), padded
        to the fixed ``write_chunk`` jit shape.  Engine-side compaction may
        swap the snapshot between chunks; the server then re-warms the jit
        cache so later read chunks stay compile-free.
        """
        keys = np.concatenate([r.a for r in reqs])
        values = np.concatenate(
            [r.b if r.op == "write" else np.zeros(r.a.size, np.int32) for r in reqs]
        )
        deletes = np.concatenate(
            [np.full(r.a.size, r.op == "delete") for r in reqs]
        )
        pad = (-keys.size) % self._write_chunk
        valid = np.ones(keys.size + pad, bool)
        if pad:
            valid[keys.size:] = False
            keys = np.pad(keys, (0, pad))
            values = np.pad(values, (0, pad))
            deletes = np.pad(deletes, (0, pad))
        before = self._engine.compactions
        t0 = time.perf_counter()
        # One engine call per _write_chunk slice: every ingest reuses the
        # single compiled program regardless of span size (the engine only
        # re-slices by its own capacity, which may be larger).
        n_calls = 0
        for lo in range(0, keys.size, self._write_chunk):
            sl = slice(lo, lo + self._write_chunk)
            self._engine.apply_ops(keys[sl], values[sl], deletes[sl], valid[sl])
            n_calls += 1
        # dispatch is async: sync on the buffer so busy_s measures the
        # ingest compute, exactly as _serve_stream syncs on query results
        jax.block_until_ready(self._engine.delta)
        dt = time.perf_counter() - t0
        n = int(valid.sum())
        self.stats.busy_s += dt
        self.stats.updates += n
        self.stats.served += n
        self.stats.chunks += n_calls
        swept = self._engine.compactions - before
        self.stats.compactions += swept
        if swept and self._warm_ops:
            self.warmup(self._warm_ops)
        self.stats.lanes += n
        for r in reqs:
            op_stats = self.stats.op(r.op)
            op_stats.served += r.a.size
            # Busy attribution is by the lanes the request actually
            # occupied in the span's engine calls (one per write/delete
            # key; ``n`` counts every occupied lane in the span, so shares
            # sum to exactly ``dt`` and padding cost is borne
            # proportionally -- a request's op kind never skews it).
            op_stats.busy_s += dt * (r.a.size / max(n, 1))
            op_stats.lanes += r.a.size
            out[r.ticket] = (np.asarray(r.a.size, np.int32),)
        for kind in {r.op for r in reqs}:
            # a mixed span's engine calls served both kinds; each kind
            # records every call it rode in (same rule as busy_s sharing)
            self.stats.op(kind).chunks += n_calls

    def _empty_columns(self, op: str):
        """Result columns for a zero-key stream (no engine call needed)."""
        if op == "lookup":
            return [np.empty(0, np.int32), np.empty(0, bool)]
        if op in ("predecessor", "successor"):
            return [np.empty(0, np.int32), np.empty(0, np.int32), np.empty(0, bool)]
        if op == "range_count":
            return [np.empty(0, np.int32)]
        k = self.scan_k
        return [
            np.empty((0, k), np.int32),
            np.empty((0, k), np.int32),
            np.empty(0, np.int32),
        ]

    def _serve_stream(self, op: str, a: np.ndarray, b: Optional[np.ndarray]):
        """Run one op's packed stream through fixed-shape engine chunks."""
        B = a.size
        if B == 0:
            return self._empty_columns(op)
        pad = (-B) % self.chunk_size
        if pad:
            a = np.pad(a, (0, pad))
            if b is not None:
                b = np.pad(b, (0, pad))
        if self._squery is not None:
            return self._serve_stream_sharded(op, a, b, B)
        columns = None
        for lo in range(0, a.size, self.chunk_size):
            sl = slice(lo, lo + self.chunk_size)
            t0 = time.perf_counter()
            res = self._query_chunk(op, a[sl], None if b is None else b[sl])
            jax.block_until_ready(res)
            dt = time.perf_counter() - t0
            real = min(self.chunk_size, B - lo)  # non-padded lanes this chunk
            # range requests occupy TWO engine lanes each: the lo||hi
            # concatenated descent (DESIGN.md §6)
            lanes = real * (2 if op in RANGE_OPS else 1)
            self.stats.busy_s += dt
            self.stats.chunks += 1
            self.stats.lanes += lanes
            ops = self.stats.op(op)
            ops.busy_s += dt
            ops.chunks += 1
            ops.lanes += lanes
            columns = self._fill_columns(columns, a.size, sl, res)
            if op == "lookup":
                # hits accumulated per chunk from the host columns the
                # retire already paid for -- no extra device sync
                self.stats.found += int(columns[1][lo : lo + real].sum())
        self.stats.served += B
        self.stats.op(op).served += B
        return [col[:B] for col in columns]

    def _fill_columns(self, columns, total: int, sl: slice, res: tuple):
        """Copy one chunk's result tuple into the stream-sized host columns.

        The ONLY place read results cross device->host: one sanctioned
        ``device_fetch`` per chunk (the retire budget the runtime gate
        asserts -- DESIGN.md §10); found counts and per-request slices all
        read the fetched host columns afterwards.
        """
        if columns is None:
            columns = [np.empty((total,) + c.shape[1:], c.dtype) for c in res]
        for col, c in zip(columns, analysis_runtime.device_fetch(res)):
            col[sl] = c
        return columns

    def _serve_stream_sharded(
        self, op: str, a: np.ndarray, b: Optional[np.ndarray], B: int
    ):
        """The async double-buffered scheduler (DESIGN.md §9).

        Chunk ``i+1`` is formed (sliced, converted, device_put) and
        DISPATCHED while chunk ``i`` is still in flight; the sync point
        trails one chunk behind dispatch, so host-side packing and result
        unpacking overlap device compute instead of serializing on a
        per-chunk ``block_until_ready``.  Busy seconds are the pipeline's
        wall time (first dispatch to last retire) -- the honest serving
        figure for an overlapped scheduler; per-chunk timings would double
        count the overlap.  Lane/found accounting is identical to the
        single-chip loop: padded lanes never reach results or counters.
        """
        columns = None
        found = 0
        inflight: List[Tuple[slice, int, tuple]] = []
        n_chunks = 0

        def retire(r_sl: slice, r_lo: int, r_res: tuple):
            nonlocal columns, found
            jax.block_until_ready(r_res)
            columns = self._fill_columns(columns, a.size, r_sl, r_res)
            if op == "lookup":
                real = min(self.chunk_size, B - r_lo)
                found += int(columns[1][r_lo : r_lo + real].sum())

        t0 = time.perf_counter()
        for lo in range(0, a.size, self.chunk_size):
            sl = slice(lo, lo + self.chunk_size)
            res = self._query_chunk(op, a[sl], None if b is None else b[sl])
            inflight.append((sl, lo, res))
            n_chunks += 1
            if len(inflight) > 1:  # depth-2 pipeline: retire the older chunk
                retire(*inflight.pop(0))
        for flying in inflight:
            retire(*flying)
        dt = time.perf_counter() - t0
        lanes = B * (2 if op in RANGE_OPS else 1)
        self.stats.busy_s += dt
        self.stats.chunks += n_chunks
        self.stats.lanes += lanes
        self.stats.found += found
        self.stats.served += B
        ops = self.stats.op(op)
        ops.busy_s += dt
        ops.chunks += n_chunks
        ops.lanes += lanes
        ops.served += B
        return [col[:B] for col in columns]

    # ------------------------------------------------------------ convenience
    def lookup(self, request_keys) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: submit one request and drain the queue."""
        ticket = self.submit(request_keys)
        return self.drain()[ticket]

    def predecessor(self, request_keys):
        ticket = self.submit(request_keys, op="predecessor")
        return self.drain()[ticket]

    def successor(self, request_keys):
        ticket = self.submit(request_keys, op="successor")
        return self.drain()[ticket]

    def range_count(self, lo, hi) -> np.ndarray:
        ticket = self.submit_range(lo, hi, op="range_count")
        return self.drain()[ticket][0]

    def range_scan(self, lo, hi):
        ticket = self.submit_range(lo, hi, op="range_scan")
        return self.drain()[ticket]

    def write(self, request_keys, request_values) -> int:
        """Synchronous upsert: submit one write request and drain."""
        ticket = self.submit_write(request_keys, request_values)
        return int(self.drain()[ticket][0])

    def delete(self, request_keys) -> int:
        """Synchronous delete: submit one tombstone request and drain."""
        ticket = self.submit_delete(request_keys)
        return int(self.drain()[ticket][0])

    # ------------------------------------------------------------- accounting
    def reset_stats(self) -> None:
        self.stats = ServerStats()

    def memory_nodes(self) -> int:
        return self._engine.memory_nodes()

    def memory_nodes_per_device(self) -> int:
        """Stored key slots on the fullest device, MEASURED from the real
        shard layout in sharded mode (DESIGN.md §9's capacity figure;
        falls back to the snapshot's node count single-chip)."""
        if self._squery is not None:
            return int(self._squery.device_nodes)
        return int(self._engine.tree.n_nodes)
