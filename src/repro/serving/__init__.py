from repro.serving.serve_loop import make_serve_step, make_prefill_fn, greedy_generate

__all__ = ["make_serve_step", "make_prefill_fn", "greedy_generate"]
