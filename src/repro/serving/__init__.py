from repro.serving.bst_server import BSTServer, OpStats, ServerStats
from repro.serving.serve_loop import make_serve_step, make_prefill_fn, greedy_generate

__all__ = [
    "BSTServer",
    "OpStats",
    "ServerStats",
    "make_serve_step",
    "make_prefill_fn",
    "greedy_generate",
]
