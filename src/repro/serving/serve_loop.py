"""Serving: jitted prefill / decode steps with sharded caches.

serve_step is the unit the decode dry-run cells lower: one new token for
every sequence in the batch against a seq_len-deep cache.  Cache layout per
family (attention KV ring buffers for SWA, SSM state, cross-attention
memory) is decided in models/; here we only wire shardings and the
request-batching driver used by the examples.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import model as M
from repro.models.config import ModelConfig


def make_serve_step(
    cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    batch: int = 1,
    seq_shard: Optional[bool] = None,  # None = auto (specs.decode_state_specs)
):
    """(params, tokens (B,1), DecodeState) -> (logits (B,V), DecodeState)."""
    step = functools.partial(M.decode_step, cfg)
    if mesh is None:
        return jax.jit(step, donate_argnums=(2,))
    from repro.sharding import specs

    sh = specs.serve_step_shardings(cfg, mesh, batch, seq_shard=seq_shard)
    return jax.jit(
        step, in_shardings=sh["in"], out_shardings=sh["out"], donate_argnums=(2,)
    )


def make_prefill_fn(
    cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    batch: int = 1,
    max_len: Optional[int] = None,
):
    """Positional signature: (params, tokens[, frontend_embeds])."""
    if cfg.frontend is not None:
        fn = lambda params, tokens, fe: M.prefill(cfg, params, tokens, fe, max_len=max_len)
    else:
        fn = lambda params, tokens: M.prefill(cfg, params, tokens, max_len=max_len)
    if mesh is None:
        return jax.jit(fn)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import specs

    ps = specs._named(mesh, specs.param_specs(cfg, mesh))
    dp = specs.dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    bdim = dp if (batch % max(ndp, 1) == 0 and ndp > 1) else None
    toks = NamedSharding(mesh, P(bdim, None))
    ins = (ps, toks)
    if cfg.frontend is not None:
        ins = ins + (NamedSharding(mesh, P(bdim, None, None)),)
    m = mesh.shape.get("model", 1)
    logits = NamedSharding(
        mesh, P(bdim, specs._maybe(cfg.vocab_size, m, "model"))
    )
    cache = specs._named(mesh, specs.decode_state_specs(cfg, mesh, batch))
    return jax.jit(fn, in_shardings=ins, out_shardings=(logits, cache))


def greedy_generate(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,  # (B, S)
    n_new: int,
    frontend_embeds: Optional[jax.Array] = None,
) -> jax.Array:
    """Batched greedy decoding driver (examples/tests)."""
    B, S = prompt_tokens.shape
    logits, state = M.prefill(
        cfg, params, prompt_tokens, frontend_embeds, max_len=S + n_new
    )
    step = make_serve_step(cfg)
    outs = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(n_new):
        outs.append(tok)
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)
