"""AdamW with fp32 master weights over bf16 compute params.

Plain-JAX (no optax dependency in this container).  State keeps fp32
master copies so bf16 training does not lose small updates; the train step
casts masters back to the compute dtype after each update.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    master: Any  # fp32 copies of params
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    # NOTE: force distinct buffers -- astype(f32) on f32 params is an alias,
    # and XLA dedupes zero constants; donated train states must never hold
    # the same buffer twice.
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32).copy()
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_schedule(
    step: jax.Array, peak_lr: float, warmup: int, total: int, floor: float = 0.1
) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = (s + 1) / jnp.maximum(warmup, 1)  # never 0: step 0 must move
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(
    grads,
    state: AdamWState,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    compute_dtype=jnp.bfloat16,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1 - b1**t
    c2 = 1 - b2**t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        update = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p
        return m, v, p - lr * update

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    params = jax.tree.map(lambda p: p.astype(compute_dtype), master)
    return params, AdamWState(step=step, master=master, mu=mu, nu=nu)
