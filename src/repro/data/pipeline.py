"""Deterministic, stateless-resumable synthetic data pipeline.

For a 1000+-node deployment the pipeline must be (a) shardable by host with
no coordination, (b) resumable from a bare step counter after preemption, and
(c) cheap.  We derive every batch from ``fold_in(seed, step)`` so a restart
at step k reproduces exactly the batches a non-failed run would have seen --
no data-loader state in the checkpoint.

Batches are token/label pairs for the LM substrate; modality frontends
(audio frames, vision patches) are stubs per the assignment and therefore
synthesized as embeddings directly where needed (see input_specs()).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Host-sharding: this host produces rows [host_index*rows : ...+rows).
    host_index: int = 0
    host_count: int = 1

    @property
    def local_batch(self) -> int:
        if self.global_batch % self.host_count:
            raise ValueError("global_batch must divide by host_count")
        return self.global_batch // self.host_count

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels), each (local_batch, seq_len) int32, pure f(step)."""
        # numpy Philox keyed on (seed, host | step) = stateless & coordination-free
        rng = np.random.default_rng(
            np.random.Philox(
                key=[(self.seed << 20) ^ self.host_index, (step << 1) | 1]
            )
        )
        tokens = rng.integers(
            0, self.vocab_size, size=(self.local_batch, self.seq_len + 1), dtype=np.int64
        ).astype(np.int32)
        return tokens[:, :-1], tokens[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
