"""Key-set generators reproducing the paper's evaluation inputs (§III).

* ``equal``  -- the same key, chosen as a LEAF node: worst case, every key
  follows the same root-to-leaf path (maximal buffer conflicts).
* ``random`` -- uniformly random keys from the inserted key population.
* ``split``  -- keys cycling round-robin over the vertical subtrees: best
  case, zero conflicts for every hybrid configuration evaluated.

Sizes used by the paper: 64K and 256K.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import tree as tree_lib
from repro.core.tree import TreeData


def make_tree_data(n_keys: int, seed: int = 0, spacing: int = 2):
    """Unique sorted int32 keys (spaced so absent keys exist) + values."""
    rng = np.random.default_rng(seed)
    keys = np.arange(1, n_keys + 1, dtype=np.int64) * spacing
    keys = keys.astype(np.int32)
    values = rng.integers(0, 2**31 - 1, size=n_keys, dtype=np.int32)
    return keys, values


def leaf_keys(tree: TreeData) -> np.ndarray:
    """Non-sentinel keys stored on the deepest level."""
    o = tree_lib.level_offset(tree.height)
    lvl = np.asarray(tree.keys)[o:]
    return lvl[lvl != tree_lib.SENTINEL_KEY]


def make_key_sets(
    tree: TreeData, size: int, n_subtrees: int = 8, seed: int = 1
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    all_keys = np.asarray(tree.keys)
    real = all_keys[all_keys != tree_lib.SENTINEL_KEY]

    # Equal: one leaf key repeated (worst case).
    leaves = leaf_keys(tree)
    equal = np.full(size, leaves[len(leaves) // 2], dtype=np.int32)

    # Random: uniform over the key population.
    random = rng.choice(real, size=size, replace=True).astype(np.int32)

    # Split: round-robin over the deepest vertical split evaluated (8), in the
    # bit-reversed order (0,2,4,6,1,3,5,7).  That order is simultaneously
    # conflict-free for the 4- and 8-subtree configs *including* the direct
    # mapping's port-half layout: subtree d receives chunk indices d and
    # d + chunk/2, one in each buffer half.
    split_level = int(np.log2(n_subtrees))
    per_sub = []
    for s in range(n_subtrees):
        sub = tree.subtree(split_level, s)
        sk = np.asarray(sub.keys)
        sk = sk[sk != tree_lib.SENTINEL_KEY]
        per_sub.append(rng.choice(sk, size=(size + n_subtrees - 1) // n_subtrees))
    order = [s for s in range(n_subtrees) if s % 2 == 0] + [
        s for s in range(n_subtrees) if s % 2 == 1
    ]
    split = (
        np.stack([per_sub[s] for s in order], axis=1).reshape(-1)[:size].astype(np.int32)
    )

    return {"equal": equal, "random": random, "split": split}
