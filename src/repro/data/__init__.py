from repro.data.keysets import make_key_sets, make_tree_data
from repro.data.pipeline import TokenPipeline

__all__ = ["make_key_sets", "make_tree_data", "TokenPipeline"]
