"""Pallas TPU kernel: forest-batched BST descent over one flat tree operand.

FPGA -> TPU mapping (DESIGN.md §2):

* the BFS (Eytzinger) array *is* the level-major BRAM image: level ``l``
  occupies the contiguous slice ``[2^l - 1, 2^{l+1} - 1)``, so ONE flat
  operand per tree replaces the seed's one-operand-per-level layout and
  makes trees of height >= 20 expressible (the per-level-operand kernel
  needed ``2 * height`` operands and a fresh ``pallas_call`` per tree);
* the register layer (top ``register_levels`` levels)  ->  a single small
  VMEM block that every query lane compares against simultaneously;
* parallel subtrees / replicas  ->  a leading *forest* dimension.  The 2-D
  grid ``(n_trees, query_chunks)`` lowers horizontal (``n_trees == 1``),
  duplicated (``shared_tree=True``: every grid row reads tree row 0) and
  hybrid (one row per vertical subtree) partitioning to the SAME kernel --
  one ``pallas_call``, no ``vmap``-of-``pallas_call``;
* dual-port keys/cycle  ->  a whole query *chunk* (``block_q`` lanes) does a
  compare-descend step per level, i.e. the level pipeline is unrolled across
  the vector unit instead of across clock cycles;
* the query-chunk grid dimension streams chunks exactly like the FPGA
  streams key chunks -- while chunk ``i`` is being compared, the DMA engine
  prefetches chunk ``i+1`` (Pallas double-buffers input blocks).

The descent's per-level gather (``flat_keys[idx]``) is a 1-D dynamic gather
within a VMEM-resident block -- the TPU analogue of a BRAM port read.
Validated in interpret mode on CPU per the container's constraints.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SENTINEL_VALUE = -1  # plain int: jnp scalars would be captured as consts


def _descend_one_level(q, idx, val, found, active, keys, vals):
    """One compare-descend step; ``idx`` is the global BFS node index."""
    safe = jnp.clip(idx, 0, keys.shape[0] - 1)
    nk = keys[safe]
    nv = vals[safe]
    hit = (nk == q) & ~found & active
    val = jnp.where(hit, nv, val)
    found = found | hit
    go_right = (q > nk).astype(idx.dtype)
    idx = jnp.where(found | ~active, idx, 2 * idx + 1 + go_right)
    return idx, val, found


def _forest_search_kernel(
    reg_k_ref,
    reg_v_ref,
    flat_k_ref,
    flat_v_ref,
    q_ref,
    act_ref,
    val_ref,
    found_ref,
    *,
    register_levels: int,
    height: int,
):
    q = q_ref[0, :]
    active = act_ref[0, :] != 0
    idx = jnp.zeros(q.shape, jnp.int32)
    val = jnp.full(q.shape, SENTINEL_VALUE, dtype=jnp.int32)
    found = jnp.zeros(q.shape, bool)

    # --- register layer: levels [0, r) live in one small broadcast block
    # (global BFS index == offset inside the register block there).
    reg_k = reg_k_ref[0, :]
    reg_v = reg_v_ref[0, :]
    for _l in range(register_levels):
        idx, val, found = _descend_one_level(q, idx, val, found, active, reg_k, reg_v)

    # --- deep levels: gathers into the flat level-major tree ("BRAM") block.
    flat_k = flat_k_ref[0, :]
    flat_v = flat_v_ref[0, :]
    for _l in range(register_levels, height + 1):
        idx, val, found = _descend_one_level(
            q, idx, val, found, active, flat_k, flat_v
        )

    val_ref[0, :] = val
    found_ref[0, :] = found.astype(jnp.int32)


def bst_search_forest_pallas(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
    shared_tree: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Search a forest of BFS-layout perfect trees in ONE ``pallas_call``.

    forest_keys/forest_values: (n_rows, n) flat level-major trees, where
    ``n = 2^{height+1} - 1``.  queries/active: (n_trees, B).  With
    ``shared_tree=True`` the operand has one row that every grid row reads
    (duplicated partitioning -- replication without materialisation).
    Returns (values, found), each (n_trees, B).
    """
    if forest_keys.ndim != 2 or queries.ndim != 2:
        raise ValueError("forest operands and queries must be 2-D")
    T, B = queries.shape
    n = forest_keys.shape[1]
    if n != (1 << (height + 1)) - 1:
        raise ValueError(f"flat operand has {n} nodes, want 2^{height + 1}-1")
    if not shared_tree and forest_keys.shape[0] != T:
        raise ValueError("need one tree row per query row (or shared_tree=True)")
    register_levels = max(1, min(register_levels, height + 1))
    if active is None:
        active = jnp.ones((T, B), bool)
    pad = (-B) % block_q
    qp = jnp.pad(queries, ((0, 0), (0, pad)))
    ap = jnp.pad(active.astype(jnp.int32), ((0, 0), (0, pad)))
    nq = qp.shape[1] // block_q

    reg_n = (1 << register_levels) - 1
    if shared_tree:
        tree_map = lambda t, i: (0, 0)  # noqa: E731 -- every grid row reads row 0
    else:
        tree_map = lambda t, i: (t, 0)  # noqa: E731
    chunk_map = lambda t, i: (t, i)  # noqa: E731

    kernel = functools.partial(
        _forest_search_kernel, register_levels=register_levels, height=height
    )
    out_val, out_found = pl.pallas_call(
        kernel,
        grid=(T, nq),
        in_specs=[
            pl.BlockSpec((1, reg_n), tree_map),
            pl.BlockSpec((1, reg_n), tree_map),
            pl.BlockSpec((1, n), tree_map),
            pl.BlockSpec((1, n), tree_map),
            pl.BlockSpec((1, block_q), chunk_map),
            pl.BlockSpec((1, block_q), chunk_map),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q), chunk_map),
            pl.BlockSpec((1, block_q), chunk_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(qp.shape, jnp.int32),
            jax.ShapeDtypeStruct(qp.shape, jnp.int32),
        ],
        interpret=interpret,
    )(
        forest_keys[:, :reg_n],
        forest_values[:, :reg_n],
        forest_keys,
        forest_values,
        qp,
        ap,
    )
    return out_val[:, :B], out_found[:, :B] != 0


def bst_search_pallas(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Single-tree convenience wrapper: a forest of one (n_trees == 1)."""
    val, found = bst_search_forest_pallas(
        tree_keys[None, :],
        tree_values[None, :],
        queries[None, :],
        height,
        active=None if active is None else active[None, :],
        register_levels=register_levels,
        block_q=block_q,
        interpret=interpret,
    )
    return val[0], found[0]
