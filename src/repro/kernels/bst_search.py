"""Pallas TPU kernel: pipelined BST descent over level-partitioned VMEM.

FPGA -> TPU mapping (DESIGN.md §2):

* one BRAM partition per tree level  ->  one pallas operand per level, each
  staged into VMEM as a whole block (BlockSpec covers the full level, the
  index_map is constant so the block is resident across grid steps);
* the register layer (top ``register_levels`` levels)  ->  a single small
  VMEM block that every query lane compares against simultaneously;
* dual-port keys/cycle  ->  a whole query *chunk* (``block_q`` lanes) does a
  compare-descend step per level, i.e. the level pipeline is unrolled across
  the vector unit instead of across clock cycles;
* the grid dimension streams query chunks exactly like the FPGA streams key
  chunks -- while chunk ``i`` is being compared, the DMA engine prefetches
  chunk ``i+1`` (Pallas double-buffers input blocks automatically).

The descent's per-level gather (``level_keys[local_idx]``) is a 1-D dynamic
gather within a VMEM-resident block -- the TPU analogue of a BRAM port read.
Validated in interpret mode on CPU per the container's constraints.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SENTINEL_VALUE = -1  # plain int: jnp scalars would be captured as consts


def _descend_one_level(
    q, idx, val, found, active, level_keys, level_vals, level_offset_
):
    """One compare-descend step against a single level block."""
    local = jnp.clip(idx - level_offset_, 0, level_keys.shape[0] - 1)
    nk = level_keys[local]
    nv = level_vals[local]
    hit = (nk == q) & ~found & active
    val = jnp.where(hit, nv, val)
    found = found | hit
    go_right = (q > nk).astype(idx.dtype)
    idx = jnp.where(found | ~active, idx, 2 * idx + 1 + go_right)
    return idx, val, found


def _bst_search_kernel(
    *refs,
    register_levels: int,
    height: int,
):
    """refs = [reg_k, reg_v, lvl_k[r..H], lvl_v[r..H] interleaved, q, active,
    out_val, out_found]."""
    n_deep = height + 1 - register_levels
    reg_k_ref, reg_v_ref = refs[0], refs[1]
    level_refs = refs[2 : 2 + 2 * n_deep]
    q_ref = refs[2 + 2 * n_deep]
    act_ref = refs[3 + 2 * n_deep]
    val_ref = refs[4 + 2 * n_deep]
    found_ref = refs[5 + 2 * n_deep]

    q = q_ref[...]
    active = act_ref[...] != 0
    idx = jnp.zeros(q.shape, jnp.int32)
    val = jnp.full(q.shape, SENTINEL_VALUE, dtype=jnp.int32)
    found = jnp.zeros(q.shape, bool)

    # --- register layer: levels [0, r) live in one broadcast block.
    reg_k = reg_k_ref[...]
    reg_v = reg_v_ref[...]
    for _l in range(register_levels):
        # global BFS index == offset inside the register block for idx < 2^r-1
        idx, val, found = _descend_one_level(
            q, idx, val, found, active, reg_k, reg_v, 0
        )

    # --- deep levels: one VMEM block ("BRAM partition") per level.
    for j in range(n_deep):
        l = register_levels + j
        lk = level_refs[2 * j][...]
        lv = level_refs[2 * j + 1][...]
        idx, val, found = _descend_one_level(
            q, idx, val, found, active, lk, lv, (1 << l) - 1
        )

    val_ref[...] = val
    found_ref[...] = found.astype(jnp.int32)


def bst_search_pallas(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Search ``queries`` in a BFS-layout perfect tree of ``height``.

    Returns (values, found).  The tree is split into a register block
    (levels [0, register_levels)) plus one operand per deeper level.
    """
    B = queries.shape[0]
    register_levels = min(register_levels, height + 1)
    if active is None:
        active = jnp.ones((B,), bool)
    pad = (-B) % block_q
    qp = jnp.pad(queries, (0, pad))
    ap = jnp.pad(active.astype(jnp.int32), (0, pad))
    nq = qp.shape[0] // block_q

    reg_n = (1 << register_levels) - 1
    inputs = [tree_keys[:reg_n], tree_values[:reg_n]]
    in_specs = [
        pl.BlockSpec((reg_n,), lambda i: (0,)),
        pl.BlockSpec((reg_n,), lambda i: (0,)),
    ]
    for l in range(register_levels, height + 1):
        o, s = (1 << l) - 1, 1 << l
        inputs += [tree_keys[o : o + s], tree_values[o : o + s]]
        in_specs += [
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ]
    inputs += [qp, ap]
    in_specs += [
        pl.BlockSpec((block_q,), lambda i: (i,)),
        pl.BlockSpec((block_q,), lambda i: (i,)),
    ]

    kernel = functools.partial(
        _bst_search_kernel, register_levels=register_levels, height=height
    )
    out_val, out_found = pl.pallas_call(
        kernel,
        grid=(nq,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    return out_val[:B], out_found[:B] != 0
