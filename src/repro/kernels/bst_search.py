"""Pallas TPU kernel: forest-batched ordered BST descent, one flat operand.

FPGA -> TPU mapping (DESIGN.md §2):

* the BFS (Eytzinger) array *is* the level-major BRAM image: level ``l``
  occupies the contiguous slice ``[2^l - 1, 2^{l+1} - 1)``, so ONE flat
  operand per tree replaces the seed's one-operand-per-level layout and
  makes trees of height >= 20 expressible (the per-level-operand kernel
  needed ``2 * height`` operands and a fresh ``pallas_call`` per tree);
* the register layer (top ``register_levels`` levels)  ->  a single small
  VMEM block that every query lane compares against simultaneously;
* parallel subtrees / replicas  ->  a leading *forest* dimension.  The 2-D
  grid ``(n_trees, query_chunks)`` lowers horizontal (``n_trees == 1``),
  duplicated (``shared_tree=True``: every grid row reads tree row 0) and
  hybrid (one row per vertical subtree) partitioning to the SAME kernel --
  one ``pallas_call``, no ``vmap``-of-``pallas_call``;
* dual-port keys/cycle  ->  a whole query *chunk* (``block_q`` lanes) does a
  compare-descend step per level, i.e. the level pipeline is unrolled across
  the vector unit instead of across clock cycles;
* the query-chunk grid dimension streams chunks exactly like the FPGA
  streams key chunks -- while chunk ``i`` is being compared, the DMA engine
  prefetches chunk ``i+1`` (Pallas double-buffers input blocks).

The datapath is ORDERED (DESIGN.md §6): besides the exact-match payload,
each compare-descend step tracks the last right-turn ancestor (the strict
predecessor), the last left-turn ancestor (the strict successor) and the
query's rank boundary -- all inside the same pipelined descent, which is
what turns the membership accelerator into a range-query engine.  The
paper's hit/miss search is the SAME kernel body unrolled in its 2-output
configuration (``ordered=False``), so lookups pay none of the tracking.

The descent's per-level gather (``flat_keys[idx]``) is a 1-D dynamic gather
within a VMEM-resident block -- the TPU analogue of a BRAM port read.
Validated in interpret mode on CPU per the container's constraints.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis import invariants

# Plain ints: jnp scalars would be captured as consts inside the kernel.
SENTINEL_VALUE = -1
NO_PRED_KEY = -(2**31)  # int32 min: identity of the max-tracked predecessor
NO_SUCC_KEY = 2**31 - 1  # int32 max: identity of the min-tracked successor


def _descend_one_level(q, state, active, keys, vals, left_size, ordered):
    """One compare-descend step; ``idx`` is the global BFS index.

    With ``ordered`` (a Python flag: the level loop is unrolled, so the
    membership configuration emits none of the tracking ops) the step also
    updates the ordered state: ``left_size`` is the left-subtree size
    ``2^{H-l} - 1`` at this level -- a right turn skips the node plus that
    whole subtree, an exact hit skips just the subtree, which is the rank
    arithmetic range queries build on (DESIGN.md §6).
    """
    idx, val, found, pk, pv, sk, sv, rank = state
    safe = jnp.clip(idx, 0, keys.shape[0] - 1)
    nk = keys[safe]
    nv = vals[safe]
    live = active & ~found
    hit = (nk == q) & live
    go_right = live & ~hit & (q > nk)
    val = jnp.where(hit, nv, val)
    found = found | hit
    if ordered:
        go_left = live & ~hit & (q < nk)
        pk = jnp.where(go_right, nk, pk)  # right-turn keys increase: last == max
        pv = jnp.where(go_right, nv, pv)
        sk = jnp.where(go_left, nk, sk)  # left-turn keys decrease: last == min
        sv = jnp.where(go_left, nv, sv)
        rank = rank + jnp.where(go_right, left_size + 1, 0)
        rank = rank + jnp.where(hit, left_size, 0)
    idx = jnp.where(found | ~active, idx, 2 * idx + 1 + go_right.astype(idx.dtype))
    return (idx, val, found, pk, pv, sk, sv, rank)


def _dispatch_lanes(dest, live, mapping: str, n_sub: int, capacity: int):
    """In-kernel buffer placement (paper §II.C.3): which lanes land in their
    subtree's dispatch buffer this chunk, and which overflow to the stall
    round.  ``mapping == 'queue'`` labels same-destination lanes 0,1,2,...
    by an exclusive prefix count (the paper's labeling network as a VPU
    cumsum); ``'direct'`` pins lane ``i`` to slot ``i % capacity`` and
    overflows on (dest, slot) collisions.  Pure lane arithmetic -- the
    buffers are never materialized because the lanes never move: a placed
    lane simply continues its descent inside its subtree's BRAM slice.
    """
    B = dest.shape[0]
    live_i = live[:, None].astype(jnp.int32)
    if mapping == "queue":
        cols = jax.lax.broadcasted_iota(jnp.int32, (1, n_sub), 1)
        onehot = (dest[:, None] == cols).astype(jnp.int32) * live_i
        label = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
        label = jnp.sum(label * onehot, axis=1)  # pick own column
        placed = live & (label < capacity)
    elif mapping == "direct":
        # Lane i may only use slot i % capacity of its destination buffer,
        # so it clashes exactly when an earlier live lane k*capacity
        # positions back shares its destination (same slot by
        # construction) -- ceil(B/capacity) - 1 shifted compares instead
        # of a (B, n_sub*capacity) collision matrix.
        clash = jnp.zeros_like(live)
        for k in range(1, -(-B // capacity)):
            off = k * capacity
            prev_live = jnp.concatenate([jnp.zeros((off,), bool), live[:-off]])
            prev_dest = jnp.concatenate(
                [jnp.full((off,), -1, jnp.int32), dest[:-off]]
            )
            clash = clash | (live & prev_live & (prev_dest == dest))
        placed = live & ~clash
    else:
        raise ValueError(f"unknown mapping {mapping!r} (want 'direct' or 'queue')")
    return placed, live & ~placed


def _forest_search_kernel(
    reg_k_ref,
    reg_v_ref,
    flat_k_ref,
    flat_v_ref,
    q_ref,
    act_ref,
    *rest_refs,
    register_levels: int,
    height: int,
    ordered: bool,
    with_delta: bool,
    dispatch: Optional[Tuple[str, int]] = None,
):
    """ONE kernel body for every configuration of the datapath: membership
    (2 output refs), ordered (7 output refs, DESIGN.md §6) and -- with
    ``dispatch`` (a static ``(mapping, capacity)`` pair, DESIGN.md §8) --
    the full hybrid pipeline: register-layer route, queue/direct dispatch
    into per-subtree lanes, vertical-subtree descent and the overflow-lane
    stall-round replay, all in this body.  With ``with_delta`` (a Python
    flag, like ``ordered``) four extra operand refs precede the outputs:
    the sorted delta buffer of pending upserts/tombstones (DESIGN.md §7),
    resolved in the same pass."""
    if with_delta:
        dk_ref, dv_ref, dt_ref, dw_ref = rest_refs[:4]
        out_refs = rest_refs[4:]
    else:
        out_refs = rest_refs
    q = q_ref[0, :]
    active = act_ref[0, :] != 0
    state = (
        jnp.zeros(q.shape, jnp.int32),  # idx
        jnp.full(q.shape, SENTINEL_VALUE, dtype=jnp.int32),  # val
        jnp.zeros(q.shape, bool),  # found
        jnp.full(q.shape, NO_PRED_KEY, dtype=jnp.int32),  # pred key
        jnp.full(q.shape, SENTINEL_VALUE, dtype=jnp.int32),  # pred value
        jnp.full(q.shape, NO_SUCC_KEY, dtype=jnp.int32),  # succ key
        jnp.full(q.shape, SENTINEL_VALUE, dtype=jnp.int32),  # succ value
        jnp.zeros(q.shape, jnp.int32),  # rank
    )

    # --- register layer: levels [0, r) live in one small broadcast block
    # (global BFS index == offset inside the register block there).  In the
    # hybrid configuration r == split_level, so this loop IS the route.
    reg_k = reg_k_ref[0, :]
    reg_v = reg_v_ref[0, :]
    for l in range(register_levels):
        state = _descend_one_level(
            q, state, active, reg_k, reg_v, (1 << (height - l)) - 1, ordered
        )

    flat_k = flat_k_ref[0, :]
    flat_v = flat_v_ref[0, :]
    if dispatch is None:
        # --- deep levels: gathers into the flat level-major ("BRAM") block.
        for l in range(register_levels, height + 1):
            state = _descend_one_level(
                q, state, active, flat_k, flat_v, (1 << (height - l)) - 1, ordered
            )
    else:
        # --- hybrid pipeline (DESIGN.md §8).  A live lane's BFS index now
        # sits at the split level; its offset there names its vertical
        # subtree (the register layer routed it).  Dispatch decides which
        # lanes the per-subtree buffers admit this chunk; placed lanes
        # descend their subtree's BRAM slice, overflow lanes sit out the
        # subtree pass and REPLAY the same levels afterwards -- the
        # in-kernel stall round (the buffers have drained by then, so the
        # replay admits everything).  Both passes start from the same
        # register-layer state: it is a valid prefix of every lane's
        # root-to-leaf path, which is what makes the replay exact.
        mapping, capacity = dispatch
        n_sub = 1 << register_levels
        live = active & ~state[2]
        dest = jnp.clip(state[0] - ((1 << register_levels) - 1), 0, n_sub - 1)
        placed, overflow = _dispatch_lanes(dest, live, mapping, n_sub, capacity)
        sub_state = state
        for l in range(register_levels, height + 1):
            sub_state = _descend_one_level(
                q,
                sub_state,
                active & ~overflow,
                flat_k,
                flat_v,
                (1 << (height - l)) - 1,
                ordered,
            )

        def replay(st):
            # The stall round re-runs the subtree levels for the deferred
            # lanes only -- the hardware's "frontend stalls while buffers
            # drain", paid only when a buffer actually overflowed (the
            # cond is the cycle cost of a stall, in kernel form).
            for l in range(register_levels, height + 1):
                st = _descend_one_level(
                    q, st, overflow, flat_k, flat_v, (1 << (height - l)) - 1, ordered
                )
            return st

        rep_state = jax.lax.cond(jnp.any(overflow), replay, lambda st: st, state)
        state = tuple(
            jnp.where(overflow, r, s) for r, s in zip(rep_state, sub_state)
        )

    _, val, found, pk, pv, sk, sv, rank = state

    if with_delta:
        # --- delta buffer: one broadcast compare against the sorted side
        # structure (the write path's "extra operand", DESIGN.md §7).
        # delta-hit > tombstone > tree-hit; the signed weights below each
        # query correct the rank to the MERGED key set.  pred/succ stay
        # tree-local: the exact merged floor/ceiling is rank selection in
        # the epilogue (core/delta.py), not a descent concern.
        dk = dk_ref[0, :]
        dv = dv_ref[0, :]
        eq = q[:, None] == dk[None, :]
        hit = jnp.any(eq, axis=1) & active
        d_val = jnp.sum(jnp.where(eq, dv[None, :], 0), axis=1)
        dead = jnp.sum(jnp.where(eq, dt_ref[0, :][None, :], 0), axis=1) != 0
        val = jnp.where(hit, jnp.where(dead, SENTINEL_VALUE, d_val), val)
        found = jnp.where(hit, ~dead, found)
        if ordered:
            lt = dk[None, :] < q[:, None]
            w_below = jnp.sum(jnp.where(lt, dw_ref[0, :][None, :], 0), axis=1)
            rank = rank + jnp.where(active, w_below, 0)

    outs = (val, found.astype(jnp.int32))
    if ordered:
        outs = outs + (pk, pv, sk, sv, rank)
    for ref, arr in zip(out_refs, outs):
        ref[0, :] = arr


def bst_ordered_forest_pallas(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
    shared_tree: bool = False,
    ordered: bool = True,
    delta: Optional[Tuple[jax.Array, ...]] = None,
    dispatch: Optional[Tuple[str, int]] = None,
) -> Tuple[jax.Array, ...]:
    """Ordered search over a forest of BFS-layout trees in ONE ``pallas_call``.

    forest_keys/forest_values: (n_rows, n) flat level-major trees, where
    ``n = 2^{height+1} - 1``.  queries/active: (n_trees, B).  With
    ``shared_tree=True`` the operand has one row that every grid row reads
    (duplicated partitioning -- replication without materialisation).

    ``delta`` optionally rides the delta write buffer (DESIGN.md §7) as
    four extra (C,) int32 operands -- sorted keys, values, tombstone flags,
    signed rank weights -- shared by every grid cell like the register
    block.  Each lane then resolves ``delta-hit > tombstone > tree-hit``
    and corrects its rank to the merged key set, still in the same pass.

    ``dispatch`` selects the hybrid configuration (DESIGN.md §8): a static
    ``(mapping, capacity)`` pair that turns the register loop into the
    route (``register_levels`` then IS the split level, and may be 0),
    places the surviving lanes into per-subtree dispatch buffers
    (queue/direct, paper §II.C.3) and replays overflow lanes through the
    deep levels after the subtree pass -- the in-kernel stall round.

    Returns per-lane (n_trees, B) arrays
    ``(values, found, pred_keys, pred_values, succ_keys, succ_values, rank)``
    -- the ordered contract of DESIGN.md §6: strict predecessor/successor
    ancestors (NO_PRED_KEY / NO_SUCC_KEY when absent) and the count of
    stored keys strictly below each query (with ``delta``: value/found/rank
    are merged; pred/succ remain tree-local, see ``core/delta.py``).
    """
    if forest_keys.ndim != 2 or queries.ndim != 2:
        raise ValueError("forest operands and queries must be 2-D")
    T, B = queries.shape
    n = forest_keys.shape[1]
    # Shared with repro.analysis.contracts (DESIGN.md §10).
    invariants.check_forest_nodes(n, height)
    if not shared_tree and forest_keys.shape[0] != T:
        raise ValueError("need one tree row per query row (or shared_tree=True)")
    if dispatch is None:
        register_levels = max(1, min(register_levels, height + 1))
    elif not 0 <= register_levels <= height:
        raise ValueError("hybrid split level must lie in [0, height]")
    if active is None:
        active = jnp.ones((T, B), bool)
    pad = (-B) % block_q
    qp = jnp.pad(queries, ((0, 0), (0, pad)))
    ap = jnp.pad(active.astype(jnp.int32), ((0, 0), (0, pad)))
    nq = qp.shape[1] // block_q

    reg_n = max((1 << register_levels) - 1, 1)
    if shared_tree:
        tree_map = lambda t, i: (0, 0)  # noqa: E731 -- every grid row reads row 0
    else:
        tree_map = lambda t, i: (t, 0)  # noqa: E731
    chunk_map = lambda t, i: (t, i)  # noqa: E731

    kernel = functools.partial(
        _forest_search_kernel,
        register_levels=register_levels,
        height=height,
        ordered=ordered,
        with_delta=delta is not None,
        dispatch=dispatch,
    )
    in_specs = [
        pl.BlockSpec((1, reg_n), tree_map),
        pl.BlockSpec((1, reg_n), tree_map),
        pl.BlockSpec((1, n), tree_map),
        pl.BlockSpec((1, n), tree_map),
        pl.BlockSpec((1, block_q), chunk_map),
        pl.BlockSpec((1, block_q), chunk_map),
    ]
    operands = [
        forest_keys[:, :reg_n],
        forest_values[:, :reg_n],
        forest_keys,
        forest_values,
        qp,
        ap,
    ]
    if delta is not None:
        shared_map = lambda t, i: (0, 0)  # noqa: E731 -- one buffer, all cells
        for arr in delta:
            if arr.ndim != 1:
                raise ValueError("delta operands must be 1-D (C,) arrays")
            in_specs.append(pl.BlockSpec((1, arr.shape[0]), shared_map))
            operands.append(arr.astype(jnp.int32)[None, :])
    n_out = 7 if ordered else 2
    out_spec = pl.BlockSpec((1, block_q), chunk_map)
    out_shape = jax.ShapeDtypeStruct(qp.shape, jnp.int32)
    outs = pl.pallas_call(
        kernel,
        grid=(T, nq),
        in_specs=in_specs,
        out_specs=[out_spec] * n_out,
        out_shape=[out_shape] * n_out,
        interpret=interpret,
    )(*operands)
    outs = tuple(o[:, :B] for o in outs)
    return (outs[0], outs[1] != 0) + outs[2:]


def bst_search_forest_pallas(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
    shared_tree: bool = False,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Membership search: the same kernel body in its 2-output configuration.

    Returns (values, found), each (n_trees, B).  One ``pallas_call``; the
    unroll skips the ordered tracking entirely (``ordered=False`` is a
    Python flag), so lookups pay nothing for the §6 datapath.  ``delta``
    rides the write buffer exactly as in the ordered configuration (minus
    the rank correction, which membership search does not track).
    """
    out = bst_ordered_forest_pallas(
        forest_keys,
        forest_values,
        queries,
        height,
        active=active,
        register_levels=register_levels,
        block_q=block_q,
        interpret=interpret,
        shared_tree=shared_tree,
        ordered=False,
        delta=delta,
    )
    return out[0], out[1]


def bst_hybrid_forest_pallas(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    split_level: int,
    mapping: str = "queue",
    capacity: int = 1,
    active: Optional[jax.Array] = None,
    block_q: int = 512,
    interpret: bool = True,
    ordered: bool = True,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, ...]:
    """The WHOLE hybrid pipeline in ONE ``pallas_call`` (DESIGN.md §8).

    tree_keys/tree_values: the (n,) flat level-major FULL tree -- the top
    ``split_level`` levels double as the register layer (one small VMEM
    block) and each vertical subtree is a BRAM slice of the same operand.
    Per ``block_q`` chunk the kernel routes through the register layer,
    places survivors into per-subtree dispatch buffers (``mapping`` x
    ``capacity``, paper §II.C.3), descends placed lanes through their
    subtree, replays overflow lanes through the same levels (the stall
    round) and resolves the ``delta`` write buffer -- no driver-level
    composition left.  Returns (B,) arrays: the 7-field ordered contract,
    or (values, found) with ``ordered=False``.
    """
    if queries.ndim != 1 or tree_keys.ndim != 1:
        raise ValueError("hybrid operands are single-tree: 1-D arrays")
    out = bst_ordered_forest_pallas(
        tree_keys[None, :],
        tree_values[None, :],
        queries[None, :],
        height,
        active=None if active is None else active[None, :],
        register_levels=split_level,
        block_q=block_q,
        interpret=interpret,
        ordered=ordered,
        delta=delta,
        dispatch=(mapping, capacity),
    )
    return tuple(o[0] for o in out)


def bst_search_pallas(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Single-tree convenience wrapper: a forest of one (n_trees == 1)."""
    val, found = bst_search_forest_pallas(
        tree_keys[None, :],
        tree_values[None, :],
        queries[None, :],
        height,
        active=None if active is None else active[None, :],
        register_levels=register_levels,
        block_q=block_q,
        interpret=interpret,
    )
    return val[0], found[0]
