"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth the kernels are property-tested
against (tests/test_kernels.py sweeps shapes & dtypes with assert_allclose).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

SENTINEL_VALUE = jnp.int32(-1)
NO_PRED_KEY = jnp.int32(-(2**31))
NO_SUCC_KEY = jnp.int32(2**31 - 1)


def bst_ordered_ref(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, ...]:
    """Ordered BFS-layout descent oracle (DESIGN.md §6).

    Returns ``(values, found, pred_keys, pred_values, succ_keys,
    succ_values, rank)`` -- bit-identical ground truth for the ordered
    forest kernel: strict predecessor/successor ancestors plus the count of
    stored keys strictly below each query.
    """
    n = tree_keys.shape[0]
    B = queries.shape[0]
    if active is None:
        active = jnp.ones((B,), dtype=bool)
    levels = jnp.arange(height + 1)
    left_sizes = ((1 << (height - levels)) - 1).astype(jnp.int32)

    def step(carry, left):
        idx, val, found, pk, pv, sk, sv, rank = carry
        nk = tree_keys[idx]
        nv = tree_values[idx]
        live = active & ~found
        hit = (nk == queries) & live
        go_right = live & ~hit & (queries > nk)
        go_left = live & ~hit & (queries < nk)
        val = jnp.where(hit, nv, val)
        found = found | hit
        pk = jnp.where(go_right, nk, pk)
        pv = jnp.where(go_right, nv, pv)
        sk = jnp.where(go_left, nk, sk)
        sv = jnp.where(go_left, nv, sv)
        rank = rank + jnp.where(go_right, left + 1, 0) + jnp.where(hit, left, 0)
        nxt = 2 * idx + 1 + go_right.astype(idx.dtype)
        idx = jnp.where(found, idx, jnp.minimum(nxt, n - 1))
        return (idx, val, found, pk, pv, sk, sv, rank), None

    init = (
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.zeros((B,), bool),
        jnp.full((B,), NO_PRED_KEY, jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.full((B,), NO_SUCC_KEY, jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.zeros((B,), jnp.int32),
    )
    (_, val, found, pk, pv, sk, sv, rank), _ = jax.lax.scan(
        step, init, left_sizes
    )
    return val, found & active, pk, pv, sk, sv, rank


def bst_search_ref(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Batched BFS-layout BST descent. Returns (values, found)."""
    n = tree_keys.shape[0]
    B = queries.shape[0]
    if active is None:
        active = jnp.ones((B,), dtype=bool)

    def step(carry, _):
        idx, val, found = carry
        nk = tree_keys[idx]
        nv = tree_values[idx]
        hit = (nk == queries) & ~found & active
        val = jnp.where(hit, nv, val)
        found = found | hit
        nxt = 2 * idx + 1 + (queries > nk).astype(idx.dtype)
        idx = jnp.where(found, idx, jnp.minimum(nxt, n - 1))
        return (idx, val, found), None

    init = (
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.zeros((B,), bool),
    )
    (_, val, found), _ = jax.lax.scan(step, init, None, length=height + 1)
    return val, found & active


def bst_hybrid_ref(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    split_level: int,
    mapping: str,
    capacity: int,
    active: Optional[jax.Array] = None,
    ordered: bool = True,
) -> Tuple[jax.Array, ...]:
    """Oracle for the in-kernel hybrid pipeline (DESIGN.md §8).

    Mirrors the kernel's phase structure over the (n,) flat FULL tree: a
    register-layer route over levels [0, split_level), queue-/direct-mapped
    dispatch of the surviving lanes into per-subtree buffers of depth
    ``capacity`` (paper §II.C.3), a subtree descent gated to the placed
    lanes, and a stall-round replay of the same levels for the overflow
    lanes -- both continuing from the shared register-layer state, which is
    a valid prefix of every root-to-leaf path (that is what makes the
    replay exact).  Returns the 7-field ordered tuple, or (values, found)
    with ``ordered=False``.  Ground truth for ``bst_hybrid_forest_pallas``;
    the composition is bit-identical to a plain full-tree descent, which
    the property tests assert independently.
    """
    n = tree_keys.shape[0]
    B = queries.shape[0]
    if active is None:
        active = jnp.ones((B,), dtype=bool)
    n_sub = 1 << split_level
    levels = jnp.arange(height + 1)
    left_sizes = ((1 << (height - levels)) - 1).astype(jnp.int32)

    def segment(state, lefts, gate):
        """Masked compare-descend over one contiguous level range."""

        def step(carry, left):
            idx, val, found, pk, pv, sk, sv, rank = carry
            nk = tree_keys[idx]
            nv = tree_values[idx]
            live = gate & ~found
            hit = (nk == queries) & live
            go_right = live & ~hit & (queries > nk)
            val = jnp.where(hit, nv, val)
            found = found | hit
            if ordered:
                go_left = live & ~hit & (queries < nk)
                pk = jnp.where(go_right, nk, pk)
                pv = jnp.where(go_right, nv, pv)
                sk = jnp.where(go_left, nk, sk)
                sv = jnp.where(go_left, nv, sv)
                rank = (
                    rank
                    + jnp.where(go_right, left + 1, 0)
                    + jnp.where(hit, left, 0)
                )
            nxt = jnp.minimum(2 * idx + 1 + go_right.astype(idx.dtype), n - 1)
            idx = jnp.where(found | ~gate, idx, nxt)
            return (idx, val, found, pk, pv, sk, sv, rank), None

        return jax.lax.scan(step, state, lefts)[0]

    state = (
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.zeros((B,), bool),
        jnp.full((B,), NO_PRED_KEY, jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.full((B,), NO_SUCC_KEY, jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        jnp.zeros((B,), jnp.int32),
    )
    # --- route: the register layer is the top of the same flat operand.
    state = segment(state, left_sizes[:split_level], active)
    idx, found = state[0], state[2]
    live = active & ~found
    dest = jnp.where(live, jnp.clip(idx - (n_sub - 1), 0, n_sub - 1), -1)

    # --- dispatch: per-subtree buffer placement (paper §II.C.3).
    if mapping == "queue":
        onehot = jax.nn.one_hot(dest, n_sub, dtype=jnp.int32)
        label = jnp.cumsum(onehot, axis=0) - onehot
        label = jnp.take_along_axis(
            label, jnp.clip(dest, 0, n_sub - 1)[:, None], axis=1
        )[:, 0]
        placed = live & (label < capacity)
    elif mapping == "direct":
        # Same shifted-compare clash test as the kernel: lane i's only
        # possible slot conflicts sit k*capacity positions earlier with
        # the same destination, so no (B, n_sub*capacity) collision
        # matrix is ever materialized (capacity here scales with the
        # whole batch -- the retired driver's O(B^2) one-hot was exactly
        # why the direct-mapped ref engines crawled on CPU).
        clash = jnp.zeros_like(live)
        for k in range(1, -(-B // capacity)):
            off = k * capacity
            prev_live = jnp.concatenate([jnp.zeros((off,), bool), live[:-off]])
            prev_dest = jnp.concatenate(
                [jnp.full((off,), -1, jnp.int32), dest[:-off]]
            )
            clash = clash | (live & prev_live & (prev_dest == dest))
        placed = live & ~clash
    else:
        raise ValueError(f"unknown mapping {mapping!r} (want 'direct' or 'queue')")
    overflow = live & ~placed

    # --- subtree descent (placed lanes) + stall-round replay (overflow,
    # paid only when a buffer actually overflowed -- the stall's cost).
    sub = segment(state, left_sizes[split_level:], active & ~overflow)
    rep = jax.lax.cond(
        jnp.any(overflow),
        lambda st: segment(st, left_sizes[split_level:], overflow),
        lambda st: st,
        state,
    )
    state = tuple(jnp.where(overflow, r, s) for r, s in zip(rep, sub))
    _, val, found, pk, pv, sk, sv, rank = state
    if not ordered:
        return val, found & active
    return val, found & active, pk, pv, sk, sv, rank


def bst_delta_resolve_ref(
    delta_keys: jax.Array,
    delta_values: jax.Array,
    delta_tombstone: jax.Array,
    delta_weight: jax.Array,
    queries: jax.Array,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Delta-buffer search oracle (DESIGN.md §7): one broadcast compare.

    Operands are the four flat int32 arrays the forest kernel rides
    (sorted keys with SENTINEL padding, values, tombstone flags, signed
    rank weights).  Returns per-query ``(hit, dead, value, weight_below)``
    where ``weight_below`` is the signed weight sum of entries strictly
    below the query -- the merged-rank correction.  Ground truth for the
    in-``pallas_call`` resolution; also the driver-level implementation
    wherever the buffer composes above the kernel (hybrid, distributed).
    Queries may have any batch shape.
    """
    q = queries[..., None]
    eq = q == delta_keys
    hit = jnp.any(eq, axis=-1)
    value = jnp.sum(jnp.where(eq, delta_values, 0), axis=-1)
    dead = jnp.sum(jnp.where(eq, delta_tombstone, 0), axis=-1) != 0
    wbelow = jnp.sum(jnp.where(delta_keys < q, delta_weight, 0), axis=-1)
    if active is not None:
        hit = hit & active
        wbelow = jnp.where(active, wbelow, 0)
    return hit, dead, value.astype(jnp.int32), wbelow.astype(jnp.int32)


def merge_delta_resolution(
    out: Tuple[jax.Array, ...],
    hit: jax.Array,
    dead: jax.Array,
    value: jax.Array,
    weight_below: jax.Array,
) -> Tuple[jax.Array, ...]:
    """Fold a ``bst_delta_resolve_ref`` resolution into descent outputs.

    ``delta-hit > tombstone > tree-hit`` on value/found, plus the merged
    rank correction when ``out`` is the 7-field ordered tuple (a 2-field
    membership tuple gets no rank lane to correct).  The ONE driver-side
    implementation of the merge every ``ops.py`` use_ref branch shares --
    the same math the kernel body applies in-``pallas_call`` and
    ``core/delta.merge_lookup``/``merge_ordered`` apply to the
    distributed engine's ``OrderedResult``.
    """
    val = jnp.where(hit, jnp.where(dead, SENTINEL_VALUE, value), out[0])
    found = jnp.where(hit, ~dead, out[1])
    if len(out) == 2:
        return val, found
    return (val, found) + out[2:6] + (out[6] + weight_below,)


def queue_dispatch_ref(
    dest: jax.Array, n_dest: int, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Queue-mapped buffers: (buffers (n_dest, capacity), counts, overflow).

    buffers holds source indices (-1 = empty); FIFO order is preserved.
    dest < 0 marks inactive items.
    """
    B = dest.shape[0]
    onehot = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)
    label = jnp.cumsum(onehot, axis=0) - onehot
    label = jnp.take_along_axis(
        label, jnp.clip(dest, 0, n_dest - 1)[:, None], axis=1
    )[:, 0]
    active = dest >= 0
    kept = active & (label < capacity)
    flat = jnp.full((n_dest * capacity + 1,), -1, jnp.int32)
    lin = jnp.where(kept, dest * capacity + label, n_dest * capacity)
    flat = flat.at[lin].set(jnp.arange(B, dtype=jnp.int32), mode="drop")
    buffers = flat[:-1].reshape(n_dest, capacity)
    counts = jnp.minimum(jnp.sum(onehot * active[:, None], axis=0), capacity)
    return buffers, counts, active & ~kept


def mha_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference attention.  q: (Sq, d), k/v: (Skv, d).  fp32 accumulation.

    ``window`` masks keys older than ``window`` positions (sliding-window
    attention); decode callers align q at the end of the kv sequence.
    """
    Sq, d = q.shape
    Skv = k.shape[0]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return (probs @ v.astype(jnp.float32)).astype(q.dtype)
