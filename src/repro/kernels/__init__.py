"""Pallas TPU kernels for the perf-critical compute layers.

  bst_search       -- the paper's search pipeline: forest-batched descent
                      over one flat level-major tree operand (DESIGN.md §2);
                      the hybrid configuration runs route + queue/direct
                      dispatch + stall-round replay in the same body (§8)
  queue_dispatch   -- the paper's queue-mapped buffers as a standalone
                      kernel (prefix-sum compaction; used by the MoE
                      dispatch benchmarks -- the BST hybrid path now
                      dispatches inside the forest kernel itself)
  flash_attention  -- LM substrate hot-spot (32k prefill cells)

Each has a pure-jnp oracle in ref.py and a jitted wrapper in ops.py.
Kernels are authored for TPU (BlockSpec VMEM tiling) and validated with
``interpret=True`` on this CPU container.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
