"""Pallas TPU kernel: blockwise online-softmax attention (flash attention).

This is the LM substrate's perf-critical compute layer: the 32k-prefill
cells are impossible with materialized (Sq, Skv) scores (32 x 32768^2 fp32
is ~137 GB per head), so prefill lowers through this kernel's blockwise
schedule.  Supports causal masking, sliding windows (Mixtral/Hymba) and
GQA via the kv index_map (no materialized head repetition).

Grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is innermost so
the VMEM scratch accumulator carries across kv steps (canonical TPU flash
pattern: init at kv==0, finalize at the last kv block).  MXU-aligned block
shapes (multiples of 128) are chosen by the wrapper.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_kv: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)  # (block_k, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)

    # absolute positions; decode-style calls align q at the end of kv
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    ) + (seq_kv - seq_q)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    l_prev = l_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        out = acc_scr[...] / safe_l[:, None]
        out = jnp.where((l == 0.0)[:, None], 0.0, out)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BHkv, Skv, d)
    v: jax.Array,  # (BHkv, Skv, d)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Batched-heads flash attention; GQA handled by the kv index_map."""
    BH, Sq, d = q.shape
    BHkv, Skv, _ = k.shape
    if BH % BHkv:
        raise ValueError("q heads must be a multiple of kv heads")
    group = BH // BHkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    if Sq % block_q or Skv % block_k:
        raise ValueError("sequence lengths must divide the block sizes")
    nq, nk = Sq // block_q, Skv // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_q=Sq,
        seq_kv=Skv,
        n_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            # GQA: query head b reads kv head b // group -- no repetition.
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # m (lane-replicated col 0)
            pltpu.VMEM((block_q, 128), jnp.float32),  # l
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
