"""Pallas TPU kernel: queue-mapped buffer placement (paper §II.C.3).

The paper's "labeling network" assigns each key the count of earlier
same-destination keys in the chunk, then stores it at write_ptr + label.
On the FPGA this serial check is the critical path that costs the queue
implementation 7-8 % clock frequency; on the TPU the same computation is a
vectorized one-hot + cumulative sum over lanes -- one of the cheapest VPU
patterns there is.  This inversion (serial labeling -> parallel prefix) is
the key hardware-adaptation insight for the whole paper: it is why the
queue mapping is strictly preferable on TPU and why we default MoE dispatch
to it (models/moe.py).

Single grid step per chunk: the chunk, label matrix and buffer image all fit
comfortably in VMEM for the paper's chunk sizes (<= a few thousand lanes).

Since DESIGN.md §8 the BST hybrid strategy no longer calls this kernel:
its dispatch executes INSIDE the forest search kernel
(``bst_search._dispatch_lanes``, the same labeling arithmetic without a
materialized buffer image, because the lanes never move).  This standalone
kernel remains the buffer-image primitive for workloads that do move
items -- the MoE dispatch benchmarks and the buffer-semantics tests.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _queue_dispatch_kernel(
    dest_ref, buf_ref, count_ref, overflow_ref, *, n_dest: int, capacity: int
):
    dest = dest_ref[...]  # (B,) int32, -1 = inactive
    B = dest.shape[0]
    active = dest >= 0
    d_safe = jnp.clip(dest, 0, n_dest - 1)

    # one-hot (B, n_dest) via broadcast compare; label = exclusive prefix count
    onehot = (
        d_safe[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_dest), 1)
    ).astype(jnp.int32) * active[:, None].astype(jnp.int32)
    label = jnp.cumsum(onehot, axis=0) - onehot
    label = jnp.sum(label * onehot, axis=1)  # pick own column

    kept = active & (label < capacity)
    # buffer image: buf[d, c] = source index i with dest[i]==d, label[i]==c
    src = jax.lax.broadcasted_iota(jnp.int32, (B,), 0)
    lin = jnp.where(kept, d_safe * capacity + label, n_dest * capacity)
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, n_dest * capacity), 1)
    match = (lin[:, None] == slots).astype(jnp.int32)  # (B, n_dest*capacity)
    filled = jnp.max(match * (src[:, None] + 1), axis=0) - 1  # -1 if empty
    buf_ref[...] = filled.reshape(n_dest, capacity)
    count_ref[...] = jnp.minimum(jnp.sum(onehot, axis=0), capacity)
    overflow_ref[...] = (active & ~kept).astype(jnp.int32)


def queue_dispatch_pallas(
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(buffers (n_dest, capacity), counts (n_dest,), overflow (B,) bool)."""
    B = dest.shape[0]
    kernel = functools.partial(
        _queue_dispatch_kernel, n_dest=n_dest, capacity=capacity
    )
    buffers, counts, overflow = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((B,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((n_dest, capacity), lambda i: (0, 0)),
            pl.BlockSpec((n_dest,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_dest, capacity), jnp.int32),
            jax.ShapeDtypeStruct((n_dest,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(dest)
    return buffers, counts, overflow != 0
