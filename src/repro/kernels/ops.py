"""Jitted public wrappers around the Pallas kernels (the ``ops.py`` contract).

Every op takes ``interpret=`` (True on this CPU container; False compiles the
Mosaic TPU kernel on real hardware) and falls back to the jnp oracle for
shapes the kernels do not cover (degenerate sizes), so callers can use these
unconditionally.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bst_search import (
    bst_hybrid_forest_pallas,
    bst_ordered_forest_pallas,
    bst_search_forest_pallas,
    bst_search_pallas,
)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.queue_dispatch import queue_dispatch_pallas


@functools.partial(
    jax.jit,
    static_argnames=(
        "height",
        "register_levels",
        "block_q",
        "interpret",
        "shared_tree",
        "use_ref",
    ),
)
def bst_search_forest(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
    shared_tree: bool = False,
    use_ref: bool = False,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Forest-batched search: (n_trees, B) queries over (n_rows, n) flat trees.

    The single entry point behind every engine strategy (DESIGN.md §2): hrz
    is a forest of one, dup shares one tree row across grid rows, hyb gives
    each vertical subtree its own row.  One ``pallas_call`` for all three.
    ``delta`` optionally rides the write buffer's four flat operands
    (DESIGN.md §7) on either path; value/found come back merged.
    """
    if use_ref:
        T = queries.shape[0]
        fk = forest_keys
        fv = forest_values
        if shared_tree:
            fk = jnp.broadcast_to(fk, (T,) + fk.shape[1:])
            fv = jnp.broadcast_to(fv, (T,) + fv.shape[1:])
        if active is None:
            active = jnp.ones(queries.shape, bool)
        out = jax.vmap(
            lambda k, v, q, a: ref.bst_search_ref(k, v, q, height, a)
        )(fk, fv, queries, active)
        if delta is not None:
            hit, dead, d_val, wb = ref.bst_delta_resolve_ref(
                *delta, queries, active
            )
            out = ref.merge_delta_resolution(out, hit, dead, d_val, wb)
        return out
    return bst_search_forest_pallas(
        forest_keys,
        forest_values,
        queries,
        height,
        active=active,
        register_levels=register_levels,
        block_q=block_q,
        interpret=interpret,
        shared_tree=shared_tree,
        delta=delta,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "height",
        "register_levels",
        "block_q",
        "interpret",
        "shared_tree",
        "use_ref",
    ),
)
def bst_ordered_forest(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
    shared_tree: bool = False,
    use_ref: bool = False,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, ...]:
    """Forest-batched ORDERED search (DESIGN.md §6): one pass per query
    yields ``(values, found, pred_keys, pred_values, succ_keys,
    succ_values, rank)``, each (n_trees, B).

    The single descent behind every ordered query op (predecessor,
    successor, range_count, range_scan) for every strategy -- same
    forest-batching contract as ``bst_search_forest``, same one
    ``pallas_call`` lowering.  ``delta`` rides the write buffer (DESIGN.md
    §7): value/found/rank come back merged against the pending
    upserts/tombstones; pred/succ stay tree-local (``core/delta.py``).
    """
    if use_ref:
        T = queries.shape[0]
        fk = forest_keys
        fv = forest_values
        if shared_tree:
            fk = jnp.broadcast_to(fk, (T,) + fk.shape[1:])
            fv = jnp.broadcast_to(fv, (T,) + fv.shape[1:])
        if active is None:
            active = jnp.ones(queries.shape, bool)
        out = jax.vmap(
            lambda k, v, q, a: ref.bst_ordered_ref(k, v, q, height, a)
        )(fk, fv, queries, active)
        if delta is not None:
            hit, dead, d_val, wb = ref.bst_delta_resolve_ref(
                *delta, queries, active
            )
            out = ref.merge_delta_resolution(out, hit, dead, d_val, wb)
        return out
    return bst_ordered_forest_pallas(
        forest_keys,
        forest_values,
        queries,
        height,
        active=active,
        register_levels=register_levels,
        block_q=block_q,
        interpret=interpret,
        shared_tree=shared_tree,
        delta=delta,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "height",
        "split_level",
        "mapping",
        "capacity",
        "block_q",
        "interpret",
        "ordered",
        "use_ref",
    ),
)
def bst_hybrid_forest(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    split_level: int,
    mapping: str = "queue",
    capacity: int = 1,
    active: Optional[jax.Array] = None,
    block_q: int = 512,
    interpret: bool = True,
    ordered: bool = True,
    use_ref: bool = False,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, ...]:
    """The hybrid strategy's single entry point (DESIGN.md §8): register
    route, queue/direct dispatch, vertical-subtree descent, stall-round
    replay and the delta-buffer merge, all in ONE ``pallas_call`` -- or the
    structurally matching jnp oracle with ``use_ref=True``.  Operands are
    the (n,) flat FULL tree and a (B,) query batch; outputs are (B,) in the
    §6 ordered contract (``(values, found)`` with ``ordered=False``).

    ``capacity`` is the per-subtree dispatch-buffer depth per chunk: the
    kernel dispatches each ``block_q`` chunk independently (the FPGA
    streams chunks), the oracle treats the whole batch as one chunk (the
    retired driver's granularity) -- results are identical either way,
    which is exactly the stall round's contract.  ``delta`` rides the
    write buffer on both paths; value/found/rank come back merged.
    """
    if use_ref:
        out = ref.bst_hybrid_ref(
            tree_keys,
            tree_values,
            queries,
            height,
            split_level,
            mapping,
            capacity,
            active=active,
            ordered=ordered,
        )
        if delta is not None:
            hit, dead, d_val, wb = ref.bst_delta_resolve_ref(
                *delta, queries, active
            )
            out = ref.merge_delta_resolution(out, hit, dead, d_val, wb)
        return out
    return bst_hybrid_forest_pallas(
        tree_keys,
        tree_values,
        queries,
        height,
        split_level,
        mapping=mapping,
        capacity=capacity,
        active=active,
        block_q=block_q,
        interpret=interpret,
        ordered=ordered,
        delta=delta,
    )


@functools.partial(
    jax.jit,
    static_argnames=("height", "register_levels", "block_q", "interpret", "use_ref"),
)
def bst_search(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    queries: jax.Array,
    height: int,
    active: Optional[jax.Array] = None,
    register_levels: int = 3,
    block_q: int = 512,
    interpret: bool = True,
    use_ref: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    if use_ref:
        return ref.bst_search_ref(tree_keys, tree_values, queries, height, active)
    return bst_search_pallas(
        tree_keys,
        tree_values,
        queries,
        height,
        active=active,
        register_levels=register_levels,
        block_q=block_q,
        interpret=interpret,
    )


@jax.jit
def bst_delta_resolve(
    delta_keys: jax.Array,
    delta_values: jax.Array,
    delta_tombstone: jax.Array,
    delta_weight: jax.Array,
    queries: jax.Array,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Delta-buffer resolution over the four flat operands (DESIGN.md §7).

    Per-query ``(hit, dead, value, weight_below)`` against the sorted write
    buffer -- the same math the forest kernels apply in-``pallas_call``
    when the buffer rides as an operand.  Public so drivers whose descent
    the kernel cannot absorb (the sharded shard_map programs, DESIGN.md
    §9) fold the REPLICATED buffer on-device through the one contract
    entry point instead of reaching into ``kernels/ref``.  ``active``
    masks lanes whose resolution must not contribute (padding, unplaced
    stall lanes): their hit drops and their rank correction zeroes.
    """
    hit, dead, value, wbelow = ref.bst_delta_resolve_ref(
        delta_keys, delta_values, delta_tombstone, delta_weight, queries
    )
    if active is not None:
        hit = hit & active
        wbelow = jnp.where(active, wbelow, 0)
    return hit, dead, value, wbelow


@functools.partial(
    jax.jit, static_argnames=("n_dest", "capacity", "interpret", "use_ref")
)
def queue_dispatch(
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    interpret: bool = True,
    use_ref: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    if use_ref:
        return ref.queue_dispatch_ref(dest, n_dest, capacity)
    return queue_dispatch_pallas(dest, n_dest, capacity, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "scale",
        "block_q",
        "block_k",
        "interpret",
        "use_ref",
    ),
)
def flash_attention(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BHkv, Skv, d)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    if use_ref:
        group = q.shape[0] // k.shape[0]
        kk = jnp.repeat(k, group, axis=0)
        vv = jnp.repeat(v, group, axis=0)
        return jax.vmap(
            lambda qq, kx, vx: ref.mha_attention_ref(
                qq, kx, vx, causal=causal, window=window, scale=scale
            )
        )(q, kk, vv)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
