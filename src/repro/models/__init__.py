from repro.models.config import ModelConfig, ShapeConfig, SHAPES, cell_is_runnable, input_specs

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "cell_is_runnable", "input_specs"]
