"""Mixture-of-Experts FFN with the paper's buffer-mapped dispatch.

The hybrid-partitioning router of the paper and a top-k MoE router solve the
same problem: a chunk of items must be placed into fixed-capacity buffers
keyed by a data-dependent destination, and collisions beyond the port budget
cost throughput.  We expose both of the paper's mappings:

  * ``queue``  (paper's contribution, our default): slot = write_ptr + label
    where the label is the prefix count of earlier same-expert tokens -- the
    dense, FIFO-preserving packing.  Overflow == the paper's frontend stall;
    in a serving system that is a dropped expert contribution for the token.
  * ``direct``: slot = token's position-derived index; cheap, but a token can
    be dropped while the expert buffer still has free slots -- exactly the
    spurious-stall behaviour of Fig. 5, surfaced here as a higher drop rate
    at equal capacity_factor (benchmarks/moe_dispatch_bench.py measures it).

Dispatch/combine are einsum-free gather/scatter on (E, C) buffers, which is
the layout expert-parallel sharding wants: buffer row e lives wherever
expert e lives.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import buffers as buf
from repro.models.config import ModelConfig


def moe_params_shape(cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": (D, E),
        "w_gate": (E, D, F),
        "w_up": (E, D, F),
        "w_down": (E, F, D),
    }


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    fair = n_tokens * cfg.top_k / cfg.n_experts
    return max(1, int(fair * cfg.capacity_factor))


def _ambient_dp_axes() -> Tuple[str, ...]:
    """Mesh DP axes at trace time ('' when tracing without a mesh)."""
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        if m.empty:
            return ()
        return tuple(a for a in ("pod", "data") if a in m.axis_names)
    except Exception:  # pragma: no cover
        return ()


def moe_ffn(
    cfg: ModelConfig, params, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, dropped_fraction).

    Tokens are split into cfg.moe_groups independent dispatch groups along
    the batch dim, carried as an explicit leading G axis that is PINNED to
    the DP mesh axes with sharding constraints.  §Perf iters 1/1r showed
    that without the pin, GSPMD replicates the (E, C, D) dispatch buffers
    and all-reduces them (21.5 GB per layer pass on mixtral-8x7b); with it,
    all dispatch/combine traffic is group-local.
    """
    B, S, D = x.shape
    G = cfg.moe_groups or 1
    if G > 1 and B % G == 0:
        xg = x.reshape(G, (B // G) * S, D)
    elif G > 1 and (B * S) % G == 0:  # decode: batch < G
        xg = x.reshape(G, (B * S) // G, D)
    else:
        xg = x.reshape(1, B * S, D)
    out, dropped = _moe_grouped(cfg, params, xg)
    return out.reshape(B, S, D).astype(x.dtype), dropped


def _moe_grouped(cfg: ModelConfig, params, xg: jax.Array):
    G, Tg, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    U = P.UNCONSTRAINED
    dp = _ambient_dp_axes()

    def cst(t, spec):
        return jax.lax.with_sharding_constraint(t, spec) if dp else t

    xg = cst(xg, P(dp, U, U))
    logits = jnp.einsum("gtd,de->gte", xg, params["router"]).astype(jnp.float32)
    gates, experts = jax.lax.top_k(logits, K)  # (G, Tg, K)
    gates = jax.nn.softmax(gates, axis=-1)

    C = expert_capacity(cfg, Tg)
    # K*Tg items per group, k-major so primary choices claim slots first.
    dest = experts.swapaxes(1, 2).reshape(G, K * Tg).astype(jnp.int32)
    plan = jax.vmap(lambda d: buf.dispatch(cfg.moe_dispatch, d, E, C))(dest)

    token_of = plan.buffers % Tg  # (G, E, C)
    token_safe = jnp.clip(token_of, 0, Tg - 1)
    live = plan.buffers >= 0
    xe = jnp.take_along_axis(xg, token_safe.reshape(G, E * C, 1), axis=1)
    xe = jnp.where(live.reshape(G, E * C, 1), xe, 0).reshape(G, E, C, D)
    xe = cst(xe, P(dp, U, U, U))

    g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    ye = cst(ye, P(dp, U, U, U))

    # combine: pure gather -- each (dest, slot) holds at most one item, so a
    # token reads its k-th expert output at (dest, slot).  (A scatter-add
    # over the token dim forced GSPMD into TB-scale all-reduces of the
    # (T, D) output image; §Perf iter 1 analysis.)
    slot = plan.slot.reshape(G, K, Tg)  # -1 when dropped
    dest_k = dest.reshape(G, K, Tg)
    lin = jnp.clip(dest_k, 0, E - 1) * C + jnp.clip(slot, 0, C - 1)
    flat_ye = ye.reshape(G, E * C, D)
    picked = jnp.take_along_axis(
        flat_ye, lin.reshape(G, K * Tg, 1), axis=1
    ).reshape(G, K, Tg, D)
    w = gates.swapaxes(1, 2).astype(jnp.float32)  # (G, K, Tg)
    w = jnp.where(slot >= 0, w, 0.0)
    out = jnp.sum(picked.astype(jnp.float32) * w[..., None], axis=1)  # (G,Tg,D)
    out = cst(out, P(dp, U, U))
    dropped = 1.0 - plan.kept.sum() / jnp.maximum(dest.size, 1)
    return out, dropped
