"""Unified model: init / train forward / prefill / decode for all families.

Structure decisions that matter at scale:
  * scan-over-layers with stacked (L, ...) params -- one layer's HLO compiled
    once and reused, keeping the 56-layer dry-run cells compilable;
  * optional jax.checkpoint (remat) around the scanned layer body;
  * caches are stacked (L, ...) pytrees threaded through the same scan;
  * losses never materialize (B, S, V) logits (layers.chunked_softmax_xent).

Families: dense / moe / ssm / hybrid / encdec / vlm (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.models.config import ModelConfig


# ------------------------------------------------------------------ param init
def _init_dense(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _layer_param_shapes(cfg: ModelConfig, role: str = "decoder") -> Dict[str, Any]:
    D = cfg.d_model
    shapes: Dict[str, Any] = {"ln1": (D,)}
    if cfg.family == "ssm":
        shapes["ssm"] = ssm.ssm_params_shape(cfg)
        return shapes
    causal_attn = attn.attn_params_shape(cfg)
    shapes["attn"] = causal_attn
    if cfg.family == "hybrid":
        shapes["ssm"] = ssm.ssm_params_shape(cfg)
    if role == "decoder" and cfg.family == "encdec":
        shapes["ln_cross"] = (D,)
        shapes["cross"] = attn.attn_params_shape(cfg, cross=True)
    shapes["ln2"] = (D,)
    if cfg.family == "moe":
        shapes["moe"] = moe.moe_params_shape(cfg)
    elif cfg.d_ff > 0:
        shapes["mlp"] = {
            "w_gate": (D, cfg.d_ff),
            "w_up": (D, cfg.d_ff),
            "w_down": (cfg.d_ff, D),
        }
    return shapes


def _init_tree(key, shapes, dtype):
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    out = [_init_dense(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dt = cfg.param_dtype
    k_embed, k_head, k_layers, k_enc, k_norm = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": _init_dense(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = _init_dense(k_head, (cfg.vocab_size, cfg.d_model), dt)

    lshapes = _layer_param_shapes(cfg, role="decoder")
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _init_tree(k, lshapes, dt))(lkeys)
    if cfg.family == "encdec":
        eshapes = _layer_param_shapes(cfg, role="encoder")
        ekeys = jax.random.split(k_enc, cfg.encoder_layers)
        params["enc_layers"] = jax.vmap(lambda k: _init_tree(k, eshapes, dt))(ekeys)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dt)
    return params


# ---------------------------------------------------------------- layer bodies
def _mix_ffn(cfg: ModelConfig, lp, h):
    if cfg.family == "moe":
        out, dropped = moe.moe_ffn(cfg, lp["moe"], h)
        return out, dropped
    if "mlp" in lp:
        return layers.swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"]), 0.0
    return jnp.zeros_like(h), 0.0


def _decoder_layer_full(cfg: ModelConfig, lp, x, positions, memory_kv, causal):
    """Full-sequence layer (train/prefill/encoder). Returns (x, aux)."""
    aux = 0.0
    h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        mixed, _ = ssm.ssd_parallel(cfg, lp["ssm"], h)
        return x + mixed, aux
    a = attn.multi_head_attention(
        cfg, lp["attn"], h, positions, causal=causal, window=cfg.sliding_window
    )
    if cfg.family == "hybrid":
        s, _ = ssm.ssd_parallel(cfg, lp["ssm"], h)
        a = (a + s) * 0.5  # parallel attention + SSM heads (hymba)
    x = x + a
    if "cross" in lp and memory_kv is not None:
        h = layers.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        c = attn.multi_head_attention(
            cfg, lp["cross"], h, positions, causal=False, window=None,
            kv_override=memory_kv,
        )
        x = x + c
    h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
    f, dropped = _mix_ffn(cfg, lp, h)
    return x + f, aux + dropped


def _stack_scan(cfg: ModelConfig, stacked_params, x, fn):
    """Scan ``fn(lp, x) -> x`` over stacked layer params, with remat.

    cfg.scan_layers=False unrolls instead -- identical math, L-times larger
    HLO; used by the roofline validation (XLA cost_analysis counts scanned
    bodies once) and available as a compile-time/perf trade-off.
    """
    def body(carry, lp):
        y, aux = fn(lp, carry[0])
        return (y, carry[1] + aux), None

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else None  # save nothing: only the per-layer scan carry survives
        )
        body = jax.checkpoint(body, policy=policy)
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry, stacked_params)
        return x, aux
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    for i in range(L):
        lp = jax.tree.map(lambda a: a[i], stacked_params)
        carry, _ = body(carry, lp)
    return carry


# ------------------------------------------------------------------- embedding
def _embed_inputs(cfg: ModelConfig, params, tokens, frontend_embeds):
    x = layers.embed(tokens, params["embed"])
    if cfg.family == "vlm" and frontend_embeds is not None:
        flen = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, flen:, :]], axis=1)
    return x


def _head_table(cfg: ModelConfig, params):
    return params["embed"] if cfg.tie_embeddings else params["head"]


def _run_encoder(cfg: ModelConfig, params, frontend_embeds):
    B, S, _ = frontend_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    fn = lambda lp, x: _decoder_layer_full(cfg, lp, x, positions, None, causal=False)
    x, _ = _stack_scan(cfg, params["enc_layers"], frontend_embeds, fn)
    return layers.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# -------------------------------------------------------------------- forward
def forward_train(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,
    labels: jax.Array,
    frontend_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean CE loss + metrics.  encdec: encoder consumes frontend embeds."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    memory_kv = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, frontend_embeds)
        x = layers.embed(tokens, params["embed"])
        fn = lambda lp, h: _decoder_layer_full(
            cfg, lp, h, positions,
            attn.project_cross_kv(cfg, lp["cross"], enc_out), causal=True,
        )
    else:
        x = _embed_inputs(cfg, params, tokens, frontend_embeds)
        fn = lambda lp, h: _decoder_layer_full(cfg, lp, h, positions, None, causal=True)
    x, aux = _stack_scan(cfg, params["layers"], x, fn)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    mask = jnp.ones((B, S), bool)
    if cfg.family == "vlm" and frontend_embeds is not None:
        mask = positions >= frontend_embeds.shape[1]
    loss = layers.chunked_softmax_xent(
        x, _head_table(cfg, params), labels, cfg.logit_chunk, mask
    )
    metrics = {"loss": loss, "moe_dropped": aux / max(cfg.n_layers, 1)}
    return loss, metrics


class DecodeState(NamedTuple):
    """Stacked per-layer caches + (encdec) cross K/V."""

    kv: Optional[attn.KVCache]  # leaves stacked (L, ...)
    ssm: Optional[ssm.SSMCache]
    cross_kv: Optional[Tuple[jax.Array, jax.Array]]  # (L, B, Smem, KV, hd)


def make_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, as_specs: bool = False
) -> DecodeState:
    """Concrete zeros (or ShapeDtypeStructs for the dry-run).

    as_specs traces the builder abstractly -- a 500k-context cache spec must
    never allocate host memory (the dry-run runs on a 35 GB box).
    """
    if as_specs:
        return jax.eval_shape(
            lambda: make_decode_state(cfg, batch, max_len, as_specs=False)
        )
    L = cfg.n_layers

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy() if a.ndim else jnp.zeros((L,), a.dtype),
            tree,
        )

    kv = sm = cross = None
    if cfg.has_attention:
        kv = stack(attn.init_kv_cache(cfg, batch, max_len))
    if cfg.family in ("ssm", "hybrid"):
        sm = stack(ssm.init_ssm_cache(cfg, batch))
    if cfg.family == "encdec":
        hd = cfg.resolved_head_dim
        shape = (L, batch, max_len, cfg.n_kv_heads, hd)
        cross = (
            jnp.zeros(shape, cfg.param_dtype),
            jnp.zeros(shape, cfg.param_dtype),
        )
    state = DecodeState(kv=kv, ssm=sm, cross_kv=cross)
    if as_specs:
        state = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state
        )
    return state


def _decoder_layer_decode(cfg: ModelConfig, lp, x, cache_kv, cache_ssm, cross_kv):
    """One-token layer step. Returns (x, new_kv, new_ssm)."""
    h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_kv, new_ssm = cache_kv, cache_ssm
    if cfg.family == "ssm":
        mixed, new_ssm = ssm.ssd_decode(cfg, lp["ssm"], h, cache_ssm)
        return x + mixed, new_kv, new_ssm
    a, new_kv = attn.decode_attention(cfg, lp["attn"], h, cache_kv)
    if cfg.family == "hybrid":
        s, new_ssm = ssm.ssd_decode(cfg, lp["ssm"], h, cache_ssm)
        a = (a + s) * 0.5
    x = x + a
    if "cross" in lp and cross_kv is not None:
        h = layers.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        c, _ = attn.decode_attention(cfg, lp["cross"], h, cache_kv, kv_override=cross_kv)
        x = x + c
    h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
    f, _ = _mix_ffn(cfg, lp, h)
    return x + f, new_kv, new_ssm


def decode_step(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,  # (B, 1)
    state: DecodeState,
) -> Tuple[jax.Array, DecodeState]:
    """One serving step: (B,1) token -> (B, V) logits + advanced caches."""
    x = layers.embed(tokens, params["embed"])

    def body(carry, inp):
        h = carry
        lp, kv_l, ssm_l, cross_l = inp
        h, new_kv, new_ssm = _decoder_layer_decode(cfg, lp, h, kv_l, ssm_l, cross_l)
        return h, (new_kv, new_ssm)

    xs = (params["layers"], state.kv, state.ssm, state.cross_kv)
    x, (new_kv, new_ssm) = jax.lax.scan(body, x, xs)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, _head_table(cfg, params))
    return logits[:, 0, :], DecodeState(new_kv, new_ssm, state.cross_kv)


def prefill(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,
    frontend_embeds: Optional[jax.Array] = None,
    max_len: Optional[int] = None,
) -> Tuple[jax.Array, DecodeState]:
    """Full-sequence pass that also builds the decode caches.

    Returns (last-position logits (B, V), DecodeState).
    """
    B, S = tokens.shape
    C = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, frontend_embeds)
        x = layers.embed(tokens, params["embed"])
    else:
        x = _embed_inputs(cfg, params, tokens, frontend_embeds)

    hd = cfg.resolved_head_dim

    def body(h, lp):
        hh = layers.rms_norm(h, lp["ln1"], cfg.norm_eps)
        new_kv = new_ssm = cross = None
        if cfg.family == "ssm":
            mixed, new_ssm = ssm.ssd_prefill(cfg, lp["ssm"], hh)
            return h + mixed, (new_kv, new_ssm, cross)
        # build KV cache from the projected full sequence
        k = hh @ lp["attn"]["wk"]
        v = hh @ lp["attn"]["wv"]
        k = k.reshape(B, S, cfg.n_kv_heads, hd)
        v = v.reshape(B, S, cfg.n_kv_heads, hd)
        cos, sin = layers.rope_angles(positions, hd, cfg.rope_theta)
        if cfg.qk_norm:
            k = layers.rms_norm(k, lp["attn"]["k_norm"], cfg.norm_eps)
        k = layers.apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
        new_kv = _ring_pack(cfg, k, v, C, S)
        a = attn.multi_head_attention(
            cfg, lp["attn"], hh, positions, causal=True, window=cfg.sliding_window
        )
        if cfg.family == "hybrid":
            sdd, new_ssm = ssm.ssd_prefill(cfg, lp["ssm"], hh)
            a = (a + sdd) * 0.5
        h = h + a
        if "cross" in lp and enc_out is not None:
            hc = layers.rms_norm(h, lp["ln_cross"], cfg.norm_eps)
            ck, cv = attn.project_cross_kv(cfg, lp["cross"], enc_out)
            c = attn.multi_head_attention(
                cfg, lp["cross"], hc, positions, causal=False, kv_override=(ck, cv)
            )
            h = h + c
            cross = (ck, cv)
        hh = layers.rms_norm(h, lp["ln2"], cfg.norm_eps)
        f, _ = _mix_ffn(cfg, lp, hh)
        return h + f, (new_kv, new_ssm, cross)

    x, (kv, sm, cross) = jax.lax.scan(body, x, params["layers"])
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1, :], _head_table(cfg, params))
    return logits, DecodeState(kv=kv, ssm=sm, cross_kv=cross)


def _ring_pack(cfg: ModelConfig, k, v, C, S) -> attn.KVCache:
    """Pack prefill K/V into the decode cache layout (ring for SWA)."""
    B = k.shape[0]
    W = min(C, cfg.sliding_window) if cfg.sliding_window else C
    if S >= W:
        # keep the last W tokens, placed at slots (pos % W): for pos in
        # [S-W, S), slot = pos % W -- a roll of the last-W slice.
        tail_k, tail_v = k[:, S - W :], v[:, S - W :]
        shift = (S - W) % W
        ck = jnp.roll(tail_k, shift, axis=1)
        cv = jnp.roll(tail_v, shift, axis=1)
    else:
        ck = jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, W - S), (0, 0), (0, 0)))
    return attn.KVCache(k=ck, v=cv, length=jnp.asarray(S, jnp.int32))
