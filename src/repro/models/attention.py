"""GQA attention: training/prefill (blockwise or Pallas flash) and decode.

Three implementations behind one interface (cfg.attention_impl):
  * "blockwise"    -- pure-jnp online-softmax scan over kv blocks.  Same
                      schedule as the flash kernel, expressed at the XLA
                      level; this is what the 32k dry-run cells lower (clean
                      HLO, bounded memory) and the CPU-executable path.
  * "flash_pallas" -- the Pallas kernel (kernels/flash_attention.py); the
                      production TPU path, validated in interpret mode.
  * "naive"        -- materialized scores, for tiny tests only.

Decode attends one new token against a KV cache; sliding-window archs use a
ring-buffer cache of length ``window`` so the 524k-context cell costs
O(window) per step (DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


class KVCache(NamedTuple):
    k: jax.Array  # (B, C, KV, hd)  C = max context (or window for SWA)
    v: jax.Array  # (B, C, KV, hd)
    length: jax.Array  # () int32 -- tokens written so far (absolute)


def attn_params_shape(cfg: ModelConfig, cross: bool = False):
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    shapes = {
        "wq": (D, H * hd),
        "wk": (D, KV * hd),
        "wv": (D, KV * hd),
        "wo": (H * hd, D),
    }
    if cfg.qk_norm and not cross:
        shapes["q_norm"] = (hd,)
        shapes["k_norm"] = (hd,)
    return shapes


def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd)


def _blockwise_attn(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,
    causal: bool,
    window: Optional[int],
    block_k: int,
    scale: float,
    block_q: int = 512,
) -> jax.Array:
    """Flash-structured attention at the XLA level.

    Outer scan over INDEPENDENT q blocks (rematerialized: backward saves only
    the per-block outputs, i.e. the attention output itself), inner online-
    softmax scan over kv blocks.  The earlier kv-outer formulation saved an
    (B, Sq, KV, G, hd) fp32 accumulator per kv step for the backward pass --
    nblk x the activation size, the dominant term of the hymba/mamba train
    baselines (§Perf iter 3).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    group = H // KV
    offset = Skv - Sq  # q positions sit at the end of the kv sequence

    nk = -(-Skv // block_k)
    pad_k = nk * block_k - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kb = kp.reshape(B, nk, block_k, KV, hd).swapaxes(0, 1)
    vb = vp.reshape(B, nk, block_k, KV, hd).swapaxes(0, 1)

    block_q = min(block_q, Sq)
    nq = -(-Sq // block_q)
    pad_q = nq * block_q - Sq
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, group, hd)
    qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    qb = qf.reshape(B, nq, block_q, KV, group, hd).swapaxes(0, 1)

    def one_q_block(qi, qblk):
        # qblk: (B, bq, KV, G, hd)
        qpos = qi * block_q + jnp.arange(block_q) + offset

        def body(carry, inp):
            m, l, acc = carry  # (B,bq,KV,G), (B,bq,KV,G), (B,bq,KV,G,hd)
            kc, vc, blk = inp
            kpos = blk * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqkgd,bpkd->bqkgp", qblk, kc.astype(jnp.float32))
            msk = jnp.broadcast_to(
                (kpos < Skv)[None, None, None, None, :], s.shape
            )
            live = (qpos < Sq + offset)
            msk = msk & live[None, :, None, None, None]
            if causal:
                cm = kpos[None, :] <= qpos[:, None]
                msk = msk & cm[None, :, None, None, :]
            if window is not None:
                wm = kpos[None, :] > qpos[:, None] - window
                msk = msk & wm[None, :, None, None, :]
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bqkgp,bpkd->bqkgd", p, vc.astype(jnp.float32))
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, block_q, KV, group), -1e30, jnp.float32),
            jnp.zeros((B, block_q, KV, group), jnp.float32),
            jnp.zeros((B, block_q, KV, group, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            body, init, (kb, vb, jnp.arange(nk, dtype=jnp.int32))
        )
        safe_l = jnp.where(l == 0, 1.0, l)
        out = acc / safe_l[..., None]
        return jnp.where((l == 0)[..., None], 0.0, out)

    ys = jax.lax.map(
        jax.checkpoint(lambda inp: one_q_block(inp[0], inp[1])),
        (jnp.arange(nq, dtype=jnp.int32), qb),
    )  # (nq, B, bq, KV, G, hd)
    out = ys.swapaxes(0, 1).reshape(B, nq * block_q, KV, group, hd)[:, :Sq]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _naive_attn(q, k, v, causal, window, scale):
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    group = H // KV
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, group, hd)
    s = jnp.einsum("bqkgd,bpkd->bqkgp", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    msk = jnp.ones((Sq, Skv), bool)
    if causal:
        msk &= kpos <= qpos
    if window is not None:
        msk &= kpos > qpos - window
    s = jnp.where(msk[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgp,bpkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _flash_pallas_attn(q, k, v, causal, window, scale, cfg: ModelConfig):
    from repro.kernels import ops as kops

    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qf = q.swapaxes(1, 2).reshape(B * H, Sq, hd)
    kf = k.swapaxes(1, 2).reshape(B * KV, k.shape[1], hd)
    vf = v.swapaxes(1, 2).reshape(B * KV, v.shape[1], hd)
    out = kops.flash_attention(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        scale=scale,
        block_q=min(cfg.attn_block_q, Sq),
        block_k=min(cfg.attn_block_k, k.shape[1]),
        interpret=True,
    )
    return out.reshape(B, H, Sq, hd).swapaxes(1, 2)


def multi_head_attention(
    cfg: ModelConfig,
    params,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S) absolute positions
    causal: bool = True,
    window: Optional[int] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    q = _split_heads(x @ params["wq"], H, hd)
    if kv_override is None:
        k = _split_heads(x @ params["wk"], KV, hd)
        v = _split_heads(x @ params["wv"], KV, hd)
        cos, sin = layers.rope_angles(positions, hd, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        if cfg.qk_norm:
            q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
            k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
    else:
        mem_k, mem_v = kv_override  # already projected (B, Smem, KV, hd)
        k, v = mem_k, mem_v
    scale = hd**-0.5
    if cfg.attention_impl == "naive":
        out = _naive_attn(q, k, v, causal, window, scale)
    elif cfg.attention_impl == "flash_pallas":
        out = _flash_pallas_attn(q, k, v, causal, window, scale, cfg)
    else:
        out = _blockwise_attn(
            q, k, v, causal, window, min(cfg.attn_block_k, k.shape[1]), scale
        )
    return out.reshape(x.shape[0], x.shape[1], H * hd) @ params["wo"]


def project_cross_kv(cfg: ModelConfig, params, memory: jax.Array):
    """Precompute cross-attention K/V from encoder memory (no rope)."""
    hd = cfg.resolved_head_dim
    k = _split_heads(memory @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(memory @ params["wv"], cfg.n_kv_heads, hd)
    return k, v


# --------------------------------------------------------------------- decode
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    """Ring buffer of ``window`` slots for SWA archs, else full ``max_len``."""
    C = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hd = cfg.resolved_head_dim
    shape = (batch, C, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, cfg.param_dtype),
        v=jnp.zeros(shape, cfg.param_dtype),
        length=jnp.zeros((), jnp.int32),
    )


def decode_attention(
    cfg: ModelConfig,
    params,
    x: jax.Array,  # (B, 1, D)
    cache: KVCache,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, KVCache]:
    """One decode step: write new KV into the (ring) cache, attend, advance."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    group = H // KV
    pos = cache.length  # absolute position of the new token

    q = _split_heads(x @ params["wq"], H, hd)
    if kv_override is None:
        k_new = _split_heads(x @ params["wk"], KV, hd)
        v_new = _split_heads(x @ params["wv"], KV, hd)
        cos, sin = layers.rope_angles(pos[None, None], hd, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        if cfg.qk_norm:
            q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
            k_new = layers.rms_norm(k_new, params["k_norm"], cfg.norm_eps)
        q = layers.apply_rope(q, cos, sin)
        k_new = layers.apply_rope(k_new, cos, sin)
        C = cache.k.shape[1]
        slot = pos % C  # ring for SWA, linear when C == max_len
        ck = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
        new_cache = KVCache(ck, cv, pos + 1)
        k_all, v_all = ck, cv
        # slot i holds absolute position: ring unwrap
        slots = jnp.arange(C)
        wrapped = pos + 1 > C
        abs_pos = jnp.where(
            wrapped,
            jnp.where(slots <= slot, pos - slot + slots, pos - slot - C + slots),
            slots,
        )
        valid = abs_pos <= pos
        if cfg.sliding_window:
            valid &= abs_pos > pos - cfg.sliding_window
    else:
        k_all, v_all = kv_override
        new_cache = cache
        valid = jnp.ones((k_all.shape[1],), bool)

    qg = (q.astype(jnp.float32) * hd**-0.5).reshape(B, 1, KV, group, hd)
    s = jnp.einsum("bqkgd,bpkd->bqkgp", qg, k_all.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgp,bpkd->bqkgd", p, v_all.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ params["wo"], new_cache
