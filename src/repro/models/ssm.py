"""Mamba2 (SSD, state-space duality) mixer: chunked-parallel and decode paths.

Recurrence (per head h, head-dim p, state n):
    a_t = exp(dt_t * A)             (A < 0, per head)
    h_t = a_t * h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D * x_t
Chunked parallel form (training/prefill): within a chunk of Q steps the
quadratic "attention-like" intra term is computed with a decay-masked
(C B^T) matrix, and a single (H, P, N) state carries across chunks via a
lax.scan -- the SSD algorithm of the mamba2 paper, with the chunk scan
keeping peak memory at (B, H, Q, Q) instead of (B, H, S, S).

Decode is the O(1) recurrent update -- this is what makes the long_500k
cell linear-cost for the ssm/hybrid architectures.

A depthwise causal conv (width 4) precedes the SSM on x, B and C, as in
the reference implementation; its tail is part of the serving cache.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

CONV_K = 4


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, CONV_K-1, d_inner + 2N) last conv inputs
    state: jax.Array  # (B, H, P, N)
    length: jax.Array  # () int32


def ssm_params_shape(cfg: ModelConfig):
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "wx": (D, di),
        "wz": (D, di),
        "wB": (D, N),
        "wC": (D, N),
        "wdt": (D, H),
        "dt_bias": (H,),
        "A_log": (H,),
        "Dskip": (H,),
        "conv_w": (CONV_K, di + 2 * N),
        "norm": (di,),
        "wo": (di, D),
    }


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, width CONV_K.  u: (B, S, C), w: (K, C)."""
    out = u * w[CONV_K - 1]
    for k in range(1, CONV_K):
        shifted = jnp.pad(u, ((0, 0), (k, 0), (0, 0)))[:, : u.shape[1], :]
        out = out + shifted * w[CONV_K - 1 - k]
    return out


def _project(cfg: ModelConfig, params, x: jax.Array):
    """x (B,S,D) -> xin (B,S,H,P), z, B_, C_, dt (after conv+activations)."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xin = x @ params["wx"]  # (B,S,di)
    z = x @ params["wz"]
    B_ = x @ params["wB"]  # (B,S,N)
    C_ = x @ params["wC"]
    dt = x @ params["wdt"]  # (B,S,H)
    raw = jnp.concatenate([xin, B_, C_], axis=-1)  # pre-conv (cache tail)
    u = _causal_conv(raw, params["conv_w"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    xin, B_, C_ = u[..., :di], u[..., di : di + N], u[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    Bsz, S = x.shape[0], x.shape[1]
    return xin.reshape(Bsz, S, H, P), z, B_, C_, dt, raw


def ssd_parallel(
    cfg: ModelConfig,
    params,
    x: jax.Array,  # (B, S, D)
    h0: jax.Array | None = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,D), final state)."""
    Bsz, S, D = x.shape
    N, H, P = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)

    xin, z, B_, C_, dt, _ = _project(cfg, params, x)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)
    # Ragged tails: pad to a chunk multiple with dt=0 -> a=1 and zero input,
    # so padded steps are identity on the state and ignored in y.
    pad = (-S) % Q
    S_orig = S
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    la = dt * A  # (B,S,H) log a_t
    dtx = xin.astype(jnp.float32) * dt[..., None]  # (B,S,H,P)

    # chunk views, scan axis first
    def chunkview(t, extra_dims):
        return t.reshape((Bsz, nc, Q) + extra_dims).swapaxes(0, 1)

    la_c = chunkview(la, (H,))
    dtx_c = chunkview(dtx, (H, P))
    B_c = chunkview(B_.astype(jnp.float32), (N,))
    C_c = chunkview(C_.astype(jnp.float32), (N,))

    tri = jnp.tril(jnp.ones((Q, Q), bool))  # j <= i

    def body(h, inp):
        la_k, dtx_k, B_k, C_k = inp  # (B,Q,H), (B,Q,H,P), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(la_k, axis=1)  # inclusive (B,Q,H)
        # intra-chunk: scores[b,h,i,j] = (C_i.B_j) exp(cum_i - cum_j), j<=i
        CB = jnp.einsum("bin,bjn->bij", C_k, B_k)
        decay = jnp.exp(
            jnp.clip(cum[:, :, None, :] - cum[:, None, :, :], -60.0, 0.0)
        )  # (B,i,j,H)
        scores = CB[..., None] * decay * tri[None, :, :, None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, dtx_k)
        # inter-chunk: y_inter[i] = exp(cum_i) * C_i . h_in
        Ch = jnp.einsum("bin,bhpn->bihp", C_k, h)
        y_inter = Ch * jnp.exp(jnp.clip(cum, -60.0, None))[..., None]
        # state update: h_out = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dtx_j B_j
        tot = cum[:, -1:, :]  # (B,1,H)
        w = jnp.exp(jnp.clip(tot - cum, -60.0, 0.0))  # (B,Q,H)
        contrib = jnp.einsum("bjh,bjhp,bjn->bhpn", w, dtx_k, B_k)
        h_new = h * jnp.exp(jnp.clip(tot[:, 0, :], -60.0, 0.0))[:, :, None, None] + contrib
        return h_new, y_intra + y_inter

    h0 = (
        h0
        if h0 is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )
    h_final, y_c = jax.lax.scan(body, h0, (la_c, dtx_c, B_c, C_c))
    y = y_c.swapaxes(0, 1).reshape(Bsz, S, H, P)
    y = y + xin.astype(jnp.float32) * params["Dskip"].astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, S, cfg.d_inner)[:, :S_orig]
    # gated RMSNorm then output projection
    y = layers.rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
        params["norm"],
        cfg.norm_eps,
    )
    return y @ params["wo"], h_final


def ssd_prefill(
    cfg: ModelConfig, params, x: jax.Array
) -> Tuple[jax.Array, SSMCache]:
    """Parallel pass that also returns the serving cache (state + conv tail)."""
    Bsz, S, _ = x.shape
    _, _, _, _, _, raw = _project(cfg, params, x)
    y, h_final = ssd_parallel(cfg, params, x)
    tail = raw[:, -(CONV_K - 1) :, :].astype(cfg.param_dtype)
    pad = CONV_K - 1 - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return y, SSMCache(
        conv=tail, state=h_final, length=jnp.asarray(S, jnp.int32)
    )


def init_ssm_cache(cfg: ModelConfig, batch: int) -> SSMCache:
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return SSMCache(
        conv=jnp.zeros((batch, CONV_K - 1, di + 2 * N), cfg.param_dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def ssd_decode(
    cfg: ModelConfig,
    params,
    x: jax.Array,  # (B, 1, D)
    cache: SSMCache,
) -> Tuple[jax.Array, SSMCache]:
    """O(1) recurrent step."""
    Bsz = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xt = x[:, 0, :]
    xin = xt @ params["wx"]
    z = xt @ params["wz"]
    B_ = xt @ params["wB"]
    C_ = xt @ params["wC"]
    dt = xt @ params["wdt"]
    u_new = jnp.concatenate([xin, B_, C_], axis=-1)  # (B, di+2N)
    win = jnp.concatenate([cache.conv, u_new[:, None, :]], axis=1)  # (B,K,ch)
    u = jnp.einsum("bkc,kc->bc", win, params["conv_w"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    xin, B_, C_ = u[:, :di], u[:, di : di + N], u[:, di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)  # (B,H)
    xh = xin.reshape(Bsz, H, P).astype(jnp.float32)
    h = cache.state * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, B_.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", C_.astype(jnp.float32), h)
    y = y + xh * params["Dskip"].astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, 1, di)
    y = layers.rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))[:, None, :]).astype(x.dtype),
        params["norm"],
        cfg.norm_eps,
    )
    new_cache = SSMCache(conv=win[:, 1:, :], state=h, length=cache.length + 1)
    return y @ params["wo"], new_cache
