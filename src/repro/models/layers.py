"""Shared layer library: norms, rope, SwiGLU MLP, embeddings, chunked CE.

Conventions:
  * activations (B, S, D); weights stored in cfg.param_dtype (bf16 on the
    production path), math that needs it (softmax, norms, CE) in fp32;
  * all parameters are plain dict pytrees so they stack cleanly for
    lax.scan-over-layers and shard with simple PartitionSpec trees.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def rope_angles(
    positions: jax.Array, head_dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """(cos, sin) of shape (..., head_dim // 2), fp32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, 1, hd/2) or broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return table[tokens]


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """x (B,S,D) @ (V,D)^T -> (B,S,V)."""
    return jnp.einsum("bsd,vd->bsv", x, table)


def chunked_softmax_xent(
    x: jax.Array,  # (B, S, D) final hidden states
    head: jax.Array,  # (V, D) output embedding
    labels: jax.Array,  # (B, S) int32
    chunk: int,
    mask: Optional[jax.Array] = None,  # (B, S) bool
) -> jax.Array:
    """Mean next-token CE with sequence-chunked logits.

    Never materializes (B, S, V): peak live logits are (B, chunk, V), which is
    what makes the 150k/256k-vocab architectures trainable at seq 4096.
    """
    B, S, D = x.shape
    if S % chunk:
        chunk = S  # degenerate/smoke shapes
    n_chunks = S // chunk
    xs = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)  # (n, B, c, D)
    ls = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    ms = (
        mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)
        if mask is not None
        else jnp.ones((n_chunks, B, chunk), bool)
    )

    def body(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        logits = jnp.einsum("bcd,vd->bcv", xc, head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc.astype(jnp.float32)
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
