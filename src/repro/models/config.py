"""Model configuration: one frozen dataclass drives all ten architectures.

Families:
  dense   -- GQA decoder LM (internlm2, granite-3, tinyllama, qwen3)
  moe     -- dense + mixture-of-experts FFN (mixtral); expert dispatch uses
             the paper's direct/queue buffer mapping (models/moe.py)
  ssm     -- attention-free mamba2 (SSD)
  hybrid  -- hymba: parallel attention + SSM heads per layer
  encdec  -- seamless-m4t: encoder + causal decoder with cross-attention
  vlm     -- internvl2: decoder LM consuming stub patch embeddings

Modality frontends ([audio]/[vlm]) are STUBS per the assignment:
``input_specs`` hands the model precomputed frame/patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 1e6
    # --- MoE (paper-technique integration point)
    n_experts: int = 0
    top_k: int = 0
    moe_dispatch: str = "queue"  # "queue" (paper's best) | "direct"
    capacity_factor: float = 1.25
    # Dispatch groups along the batch dim.  None = one global group (the
    # naive baseline: a global prefix-sum that GSPMD cannot shard -- kept
    # selectable for the §Perf before/after).  With groups aligned to the
    # DP shards, dispatch is device-local (GShard-style capacity groups).
    moe_groups: int | None = None
    # --- attention extras
    sliding_window: Optional[int] = None
    # --- SSM (mamba2 SSD / hymba heads)
    ssm_state: int = 0
    ssm_expand: int = 1
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- encoder-decoder
    encoder_layers: int = 0
    # --- modality frontend stub
    frontend: Optional[str] = None  # "audio" | "vision"
    frontend_len: int = 0  # frames/patches prepended
    # --- numerics / implementation switches
    dtype: str = "bfloat16"
    # "tp": params sharded over the model axis (baseline).  "dp_only":
    # params replicated, batch sharded over EVERY mesh axis -- zero
    # activation collectives; right call when params fit per chip and
    # global_batch >= chips (§Perf iter 4).
    sharding_strategy: str = "tp"
    # ZeRO-1: shard optimizer master/mu/nu over the data axis along each
    # leaf's leading dim (stacked layers: L % data == 0) -- grads arrive
    # reduce-scattered instead of all-reduced, opt memory /data (§Perf iter 5)
    zero1: bool = False
    attention_impl: str = "blockwise"  # blockwise | flash_pallas | naive
    attn_block_q: int = 512
    attn_block_k: int = 1024
    remat: bool = True
    remat_policy: str = "none"  # "none" (save scan carries only) | "dots"
    scan_layers: bool = True  # False: unroll (exact HLO costs, slower compile)
    logit_chunk: int = 512  # sequence-chunked cross-entropy

    # ------------------------------------------------------------------ props
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: SSM state and/or sliding-window KV."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def param_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        """Total parameter count (for 6·N·D roofline bookkeeping)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        H, KV = self.n_heads, self.n_kv_heads
        per_layer = 0
        if self.has_attention:
            per_layer += D * H * hd + 2 * D * KV * hd + H * hd * D  # q k v o
            per_layer += 2 * D  # norms
            if self.qk_norm:
                per_layer += 2 * hd
        if self.family == "moe":
            per_layer += D * self.n_experts  # router
            per_layer += self.n_experts * 3 * D * F
        elif F > 0:
            per_layer += 3 * D * F  # swiglu
        if self.family in ("ssm", "hybrid"):
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += 2 * D * di + 2 * D * N + D * Hs + di * D  # x z B C dt o
            per_layer += 3 * Hs + di  # A, D, dt_bias, gated-norm scale
            per_layer += 4 * (di + 2 * N)  # depthwise conv (width 4)
            if self.family == "ssm":
                per_layer += D  # ln1 (attention branch adds norms otherwise)
        n = self.n_layers * per_layer
        if self.family == "encdec":
            n += self.encoder_layers * (
                D * H * hd + 2 * D * KV * hd + H * hd * D + 3 * D * F + 2 * D
            )
            # decoder cross-attention
            n += self.n_layers * (D * H * hd + 2 * D * KV * hd + H * hd * D + D)
        n += V * D  # embedding
        if not self.tie_embeddings:
            n += V * D  # output head
        n += D  # final norm
        return n

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        D, F = self.d_model, self.d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * D * F
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason).  long_500k needs sub-quadratic decode."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False, (
            "pure full-attention arch: 524k dense-KV decode is the quadratic "
            "case long_500k excludes (DESIGN.md §4)"
        )
    return True, ""


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, for_smoke: bool = False
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   tokens/labels (B, S)
    prefill: tokens (B, S)
    decode:  tokens (B, 1) + the KV/SSM cache pytree is created separately by
             serving.make_cache_specs (it depends on arch internals).
    Frontends contribute precomputed embeddings (stub).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.param_dtype
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.frontend is not None and shape.kind != "decode":
        # encdec (audio): encoder consumes a frame per position -> length S.
        # vlm (vision): a fixed budget of patch embeddings overrides the
        # first ``frontend_len`` decoder positions (total length stays S).
        flen = S if cfg.family == "encdec" else cfg.frontend_len
        specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, flen, cfg.d_model), dt)
    # encdec decode: encoder memory + cross-KV live in the cache pytree
    # (serving.make_cache_specs), not here.
    return specs
