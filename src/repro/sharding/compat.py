"""Version-compat shims for the jax sharding APIs this repo leans on.

The repo targets the ``jax.shard_map`` / ``jax.sharding.AxisType`` surface;
older jax (e.g. 0.4.x, as in this container) ships ``shard_map`` under
``jax.experimental`` with the ``check_rep`` spelling and ``jax.make_mesh``
without ``axis_types``.  Every call site goes through these two helpers so
the difference lives in exactly one place.
"""

from __future__ import annotations

from typing import Sequence

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` when present, else the experimental spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Auto-typed mesh on new jax; plain mesh where AxisType predates."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes,
            axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)
