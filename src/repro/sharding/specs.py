"""PartitionSpec policies per architecture (DP / TP / EP / SP).

Axis roles on the production mesh (launch/mesh.py):
  pod   -- data parallelism across pods (gradient all-reduce rides ICI/DCN)
  data  -- data parallelism within a pod
  model -- tensor parallelism: attention heads / FFN width / vocab rows /
           expert inner width / SSM heads; also the BST engine's vertical
           subtree axis (core/distributed.py)

Dimension-size rules enforced here: a dim is only sharded when divisible by
the axis size; otherwise it falls back to replication (GSPMD would pad, but
padding wastes roofline, so we prefer explicit fallback and record it).

The functions return pytrees of NamedSharding matching the corresponding
params/state/batch pytrees, used as pjit in_shardings by the dry-run,
launcher and checkpoint reshard.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _maybe(dim: int, size: int, axis: str) -> Optional[str]:
    """Shard ``dim`` over ``axis`` of ``size`` only if divisible."""
    return axis if size > 1 and dim % size == 0 else None


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpec tree matching model.init_params(cfg, ...)."""
    m = _axis(mesh, "model")
    if cfg.sharding_strategy == "dp_only":
        m = 1  # every _maybe() falls back to replication
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads

    def attn_spec():
        s = {
            # (D, H*hd): shard the head dim product over model
            "wq": P(None, _maybe(H * hd, m, "model")),
            "wk": P(None, _maybe(KV * hd, m, "model")),
            "wv": P(None, _maybe(KV * hd, m, "model")),
            "wo": P(_maybe(H * hd, m, "model"), None),
        }
        if cfg.qk_norm:
            s["q_norm"] = P(None)
            s["k_norm"] = P(None)
        return s

    def mlp_spec():
        return {
            "w_gate": P(None, _maybe(F, m, "model")),
            "w_up": P(None, _maybe(F, m, "model")),
            "w_down": P(_maybe(F, m, "model"), None),
        }

    def moe_spec():
        return {
            "router": P(None, None),
            # experts replicated across E dim, TP inside each expert
            "w_gate": P(None, None, _maybe(F, m, "model")),
            "w_up": P(None, None, _maybe(F, m, "model")),
            "w_down": P(None, _maybe(F, m, "model"), None),
        }

    def ssm_spec():
        di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        return {
            "wx": P(None, _maybe(di, m, "model")),
            "wz": P(None, _maybe(di, m, "model")),
            "wB": P(None, None),
            "wC": P(None, None),
            "wdt": P(None, _maybe(Hs, m, "model")),
            "dt_bias": P(_maybe(Hs, m, "model")),
            "A_log": P(_maybe(Hs, m, "model")),
            "Dskip": P(_maybe(Hs, m, "model")),
            "conv_w": P(None, None),
            "norm": P(_maybe(di, m, "model")),
            "wo": P(_maybe(di, m, "model"), None),
        }

    def layer_spec(role: str):
        s: Dict[str, Any] = {"ln1": P(None)}
        if cfg.family == "ssm":
            s["ssm"] = ssm_spec()
            return s
        s["attn"] = attn_spec()
        if cfg.family == "hybrid":
            s["ssm"] = ssm_spec()
        if role == "decoder" and cfg.family == "encdec":
            s["ln_cross"] = P(None)
            s["cross"] = attn_spec()
        s["ln2"] = P(None)
        if cfg.family == "moe":
            s["moe"] = moe_spec()
        elif cfg.d_ff > 0:
            s["mlp"] = mlp_spec()
        return s

    def add_layer_dim(tree):
        return jax.tree.map(
            lambda p: P(*((None,) + tuple(p))), tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    specs: Dict[str, Any] = {
        "embed": P(_maybe(V, m, "model"), None),  # vocab rows over model
        "final_norm": P(None),
        "layers": add_layer_dim(layer_spec("decoder")),
    }
    if not cfg.tie_embeddings:
        specs["head"] = P(_maybe(V, m, "model"), None)
    if cfg.family == "encdec":
        specs["enc_layers"] = add_layer_dim(layer_spec("encoder"))
        specs["enc_final_norm"] = P(None)
    return specs


def state_specs(cfg: ModelConfig, mesh: Mesh):
    """Specs for TrainState(params, AdamWState, error_feedback)."""
    from repro.models import model as M
    from repro.optim.optimizer import AdamWState
    from repro.training.train_loop import TrainState

    ps = param_specs(cfg, mesh)
    opt_ps = ps
    if cfg.zero1 and "data" in mesh.shape and mesh.shape["data"] > 1:
        d = mesh.shape["data"]
        shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))

        def zero_shard(spec: P, shape) -> P:
            dims = list(tuple(spec) + (None,) * (len(shape.shape) - len(tuple(spec))))
            # first unsharded dim divisible by the data axis gets sharded
            for i, n in enumerate(shape.shape):
                if dims[i] is None and n % d == 0:
                    dims[i] = "data"
                    return P(*dims)
            return spec

        opt_ps = jax.tree.map(
            zero_shard, ps, shapes, is_leaf=lambda x: isinstance(x, P)
        )
    return TrainState(
        params=ps,
        opt=AdamWState(step=P(), master=opt_ps, mu=opt_ps, nu=opt_ps),
        error_feedback=(),
    )


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    dp = all_axes(mesh) if cfg.sharding_strategy == "dp_only" else dp_axes(mesh)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "frontend": P(dp, None, None),
    }


def _named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), tree, is_leaf=lambda x: isinstance(x, P)
    )


def train_step_shardings(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Any]:
    """in/out shardings for make_train_step's (state, tokens, labels[, fe])."""
    ss = _named(mesh, state_specs(cfg, mesh))
    bs = batch_specs(cfg, mesh)
    ins = (
        ss,
        NamedSharding(mesh, bs["tokens"]),
        NamedSharding(mesh, bs["labels"]),
    )
    if cfg.frontend is not None:
        ins = ins + (NamedSharding(mesh, bs["frontend"]),)
    rep = NamedSharding(mesh, P())
    return {"in": ins, "out": (ss, rep)}


# ------------------------------------------------------------------- serving
def decode_state_specs(
    cfg: ModelConfig, mesh: Mesh, batch: int, seq_shard: bool | None = None
):
    """Specs for model.DecodeState: batch over DP when divisible, heads/state
    over model.

    seq_shard=True shards the cache *sequence* dim over the model axis
    instead of kv heads (sequence-parallel decode): each chip owns 1/m of
    the window, computes partial attention, and GSPMD reduces the tiny
    softmax statistics -- the fix for archs whose kv_heads < model size,
    where head-sharding falls back to replication (§Perf iter 2).
    Default (None) = auto: seq-shard exactly when head-sharding can't fire
    (adopted after iter 2: 9x memory / 3400x collective reduction).
    """
    from repro.models import attention as attn_mod
    from repro.models import ssm as ssm_mod
    from repro.models.model import DecodeState

    m = _axis(mesh, "model")
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    bdim = dp if (batch % max(ndp, 1) == 0 and ndp > 1) else None
    kv_head = _maybe(cfg.n_kv_heads, m, "model")
    if seq_shard is None:  # auto: sequence-parallel cache iff heads can't shard
        seq_shard = cfg.has_attention and kv_head is None and m > 1

    kv = sm = cross = None
    if cfg.has_attention:
        if seq_shard:
            kv = attn_mod.KVCache(
                k=P(None, bdim, "model", None, None),
                v=P(None, bdim, "model", None, None),
                length=P(None),
            )
        else:
            kv = attn_mod.KVCache(
                k=P(None, bdim, None, kv_head, None),
                v=P(None, bdim, None, kv_head, None),
                length=P(None),
            )
    if cfg.family in ("ssm", "hybrid"):
        sm = ssm_mod.SSMCache(
            conv=P(None, bdim, None, None),
            state=P(None, bdim, _maybe(cfg.ssm_heads, m, "model"), None, None),
            length=P(None),
        )
    if cfg.family == "encdec":
        cross = (
            P(None, bdim, None, kv_head, None),
            P(None, bdim, None, kv_head, None),
        )
    return DecodeState(kv=kv, ssm=sm, cross_kv=cross)


def serve_step_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, seq_shard: bool = False):
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    bdim = dp if (batch % max(ndp, 1) == 0 and ndp > 1) else None
    ps = _named(mesh, param_specs(cfg, mesh))
    toks = NamedSharding(mesh, P(bdim, None))
    cache = _named(mesh, decode_state_specs(cfg, mesh, batch, seq_shard=seq_shard))
    m = _axis(mesh, "model")
    logits = NamedSharding(mesh, P(bdim, _maybe(cfg.vocab_size, m, "model")))
    return {"in": (ps, toks, cache), "out": (logits, cache)}
