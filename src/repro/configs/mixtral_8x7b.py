"""mixtral-8x7b [moe]: 8 experts top-2, SWA.

Assignment: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8e top-2, sliding window 4096 [arXiv:2401.04088; hf].

Expert dispatch uses the paper's queue mapping by default
(moe_dispatch="queue"); "direct" selects the position-mapped variant for
the Fig.5-style drop-rate comparison (benchmarks/moe_dispatch_bench.py).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    moe_dispatch="queue",
    capacity_factor=1.25,
    sliding_window=4096,
    rope_theta=1e6,
    # adopted after §Perf iters 1p/5: DP-pinned dispatch groups + ZeRO-1
    moe_groups=32,
    zero1=True,
)
