"""hymba-1.5b [hybrid]: parallel attention + mamba heads per layer.

Assignment: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 [arXiv:2411.13676; hf].  Head dim 64.  Simplifications noted
in DESIGN.md §4: every layer uses SWA (the published model keeps 3 global
layers; homogeneous layers keep the (L, ...) scan stackable) and meta
tokens are omitted.  The SSM branch runs at expand=1 with 16-dim state.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=1,
    ssm_head_dim=64,
    sliding_window=1024,
    rope_theta=1e4,
)
