"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

Assignment: 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf].  12 encoder + 12 decoder layers; the speech
frontend (w2v-BERT conformer) is a STUB -- input_specs() supplies
precomputed frame embeddings of width d_model (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    rope_theta=1e4,
)
