"""mixtral-8x22b [moe]: 8 experts top-2, SWA.

Assignment: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8e top-2, sliding window 4096 [arXiv:2401.04088; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    moe_dispatch="queue",
    capacity_factor=1.25,
    sliding_window=4096,
    rope_theta=1e6,
    # adopted after §Perf iters 1p/5: DP-pinned dispatch groups + ZeRO-1
    moe_groups=32,
    zero1=True,
)
