"""Architecture registry: one module per assigned architecture (+ BST engine).

``get_config(name)`` returns the full published config; ``smoke_config(name)``
returns a reduced same-family config for CPU smoke tests (small layers/width,
few experts, tiny vocab -- per the assignment only the dry-run exercises the
full shapes).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "seamless_m4t_medium",
    "hymba_1p5b",
    "internlm2_1p8b",
    "granite_3_8b",
    "tinyllama_1p1b",
    "qwen3_1p7b",
    "mixtral_8x7b",
    "mixtral_8x22b",
    "mamba2_1p3b",
    "internvl2_2b",
]

# CLI aliases (--arch) matching the assignment spelling.
ALIASES: Dict[str, str] = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "hymba-1.5b": "hymba_1p5b",
    "internlm2-1.8b": "internlm2_1p8b",
    "granite-3-8b": "granite_3_8b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "qwen3-1.7b": "qwen3_1p7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-1.3b": "mamba2_1p3b",
    "internvl2-2b": "internvl2_2b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: 2 layers, narrow, tiny vocab, fp32."""
    cfg = get_config(name)
    heads = min(cfg.n_heads, 4)
    kv = min(cfg.n_kv_heads, heads)
    while kv > 1 and heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        n_layers=2,
        encoder_layers=2 if cfg.family == "encdec" else 0,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=503,
        n_experts=4 if cfg.n_experts else 0,
        moe_groups=None,  # smoke batches are tiny: one dispatch group
        zero1=False,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        sliding_window=16 if cfg.sliding_window else None,
        frontend_len=8 if cfg.frontend == "vision" else 0,
        dtype="float32",
        attention_impl="naive",
        remat=False,
        logit_chunk=8,
    )


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
