"""internvl2-2b [vlm]: InternViT (stub) + internlm2-1.8b backbone.

Assignment: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf].  The vision frontend is a STUB: input_specs()
supplies 256 precomputed patch embeddings (448px, patch 14, pixel-shuffle
x0.5) that override the first 256 decoder positions; loss is masked there.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    frontend_len=256,
    rope_theta=1e6,
)
