"""Direct- and queue-mapped dispatch buffers (paper §II.C.3), vectorized.

The paper routes keys leaving the register layer into per-subtree buffers:

* **Direct mapping** -- key at chunk index ``i`` may only occupy slot ``i`` of
  its destination buffer.  Cheap routing; spurious stalls when slot ``i`` is
  busy while other slots are free.
* **Queue mapping** -- same-destination keys are *labeled* 0,1,2,... within the
  chunk (a segmented prefix sum) and stored at ``write_ptr + label``.  Dense
  packing, FIFO order, fewer stalls, at the cost of the labeling network.

On TPU the labeling network is a cumulative sum over vector lanes -- cheap --
which is exactly the capacity-based token dispatch used by MoE routers.  These
primitives therefore serve double duty: they implement the BST engine's hybrid
partitioning *and* the Mixtral expert dispatch (see models/moe.py).

All functions are shape-polymorphic pure JAX and jit/vmap/shard_map safe.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DispatchPlan(NamedTuple):
    """Result of mapping a chunk of items onto (n_dest, capacity) buffers.

    slot:     (B,) int32 -- assigned slot within the destination buffer, or -1
              when the item overflowed (it must retry in a later round: the
              software analogue of the paper's frontend stall).
    kept:     (B,) bool  -- item landed in a buffer this round.
    buffers:  (n_dest, capacity) int32 -- chunk indices, -1 for empty slots.
    counts:   (n_dest,) int32 -- occupied slots per destination.
    overflow: (B,) bool  -- ~kept for active items.
    """

    slot: jax.Array
    kept: jax.Array
    buffers: jax.Array
    counts: jax.Array
    overflow: jax.Array


def _scatter_buffers(
    dest: jax.Array, slot: jax.Array, kept: jax.Array, n_dest: int, capacity: int
) -> jax.Array:
    """Scatter chunk indices into the (n_dest, capacity) buffer image."""
    B = dest.shape[0]
    flat = jnp.full((n_dest * capacity,), -1, dtype=jnp.int32)
    lin = jnp.where(kept, dest * capacity + slot, n_dest * capacity)
    flat = jnp.concatenate([flat, jnp.zeros((1,), jnp.int32)])  # overflow sink
    flat = flat.at[lin].set(jnp.arange(B, dtype=jnp.int32), mode="drop")
    return flat[:-1].reshape(n_dest, capacity)


def queue_dispatch(
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    active: jax.Array | None = None,
    base: jax.Array | None = None,
) -> DispatchPlan:
    """Queue mapping: slot = write_ptr(dest) + |earlier same-dest items|.

    ``base`` optionally carries the per-destination write pointers (occupancy)
    from previous rounds, so stateful cycle simulation and stateless MoE
    dispatch share one primitive.
    """
    B = dest.shape[0]
    active = (dest >= 0) if active is None else (active & (dest >= 0))
    dest = jnp.where(active, dest, -1)
    # Segmented prefix count: label[i] = #{j < i : dest[j] == dest[i]}.
    onehot = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)  # (B, n_dest)
    label = jnp.cumsum(onehot, axis=0) - onehot  # exclusive
    label = jnp.take_along_axis(
        label, jnp.clip(dest, 0, n_dest - 1)[:, None], axis=1
    )[:, 0]
    if base is not None:
        label = label + base[jnp.clip(dest, 0, n_dest - 1)]
    slot = jnp.where(active, label, -1)
    kept = active & (slot >= 0) & (slot < capacity)
    slot = jnp.where(kept, slot, -1)
    counts = jnp.sum(
        jax.nn.one_hot(jnp.where(kept, dest, -1), n_dest, dtype=jnp.int32), axis=0
    )
    buffers = _scatter_buffers(dest, slot, kept, n_dest, capacity)
    return DispatchPlan(slot, kept, buffers, counts, active & ~kept)


def direct_dispatch(
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    active: jax.Array | None = None,
    occupied: jax.Array | None = None,
) -> DispatchPlan:
    """Direct mapping: item at chunk index ``i`` may only use slot ``i % capacity``.

    ``occupied`` optionally carries per-(dest, slot) occupancy from previous
    rounds (the cycle simulator's buffer image); a set bit blocks placement
    even when other slots are free -- the paper's spurious-stall case.
    Within a single chunk two items can also collide on (dest, slot) when
    B > capacity; the earlier item wins, as in hardware.
    """
    B = dest.shape[0]
    active = (dest >= 0) if active is None else (active & (dest >= 0))
    dest = jnp.where(active, dest, -1)
    idx = jnp.arange(B, dtype=jnp.int32)
    slot = idx % capacity

    blocked = jnp.zeros((B,), dtype=bool)
    if occupied is not None:
        blocked = occupied[jnp.clip(dest, 0, n_dest - 1), slot] & active

    # Intra-chunk collision: same (dest, slot) pair claimed twice.
    pair = dest * capacity + slot
    onehot = jax.nn.one_hot(pair, n_dest * capacity, dtype=jnp.int32)
    earlier = jnp.cumsum(onehot, axis=0) - onehot
    clash = (
        jnp.take_along_axis(earlier, jnp.clip(pair, 0, None)[:, None], axis=1)[:, 0]
        > 0
    )
    kept = active & ~blocked & ~clash
    slot = jnp.where(kept, slot, -1)
    counts = jnp.sum(
        jax.nn.one_hot(jnp.where(kept, dest, -1), n_dest, dtype=jnp.int32), axis=0
    )
    buffers = _scatter_buffers(dest, slot, kept, n_dest, capacity)
    return DispatchPlan(slot, kept, buffers, counts, active & ~kept)


def dispatch(
    mapping: str,
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    active: jax.Array | None = None,
) -> DispatchPlan:
    if mapping == "queue":
        return queue_dispatch(dest, n_dest, capacity, active)
    if mapping == "direct":
        return direct_dispatch(dest, n_dest, capacity, active)
    raise ValueError(f"unknown mapping {mapping!r} (want 'direct' or 'queue')")


def gather_from_buffers(
    items: jax.Array, buffers: jax.Array, fill_value=0
) -> jax.Array:
    """Materialize buffered items: (B, ...) -> (n_dest, capacity, ...)."""
    safe = jnp.clip(buffers, 0, items.shape[0] - 1)
    out = items[safe]
    mask = (buffers >= 0).reshape(buffers.shape + (1,) * (items.ndim - 1))
    return jnp.where(mask, out, fill_value)


def combine_to_chunk(
    per_dest: jax.Array, buffers: jax.Array, chunk_size: int, fill_value=0
) -> jax.Array:
    """Inverse of gather_from_buffers: (n_dest, capacity, ...) -> (B, ...)."""
    flat_idx = buffers.reshape(-1)
    flat_val = per_dest.reshape((-1,) + per_dest.shape[2:])
    out_shape = (chunk_size,) + per_dest.shape[2:]
    out = jnp.full(out_shape, fill_value, dtype=per_dest.dtype)
    sink = jnp.where(flat_idx >= 0, flat_idx, chunk_size)
    out = jnp.concatenate(
        [out, jnp.zeros((1,) + per_dest.shape[2:], per_dest.dtype)]
    )
    out = out.at[sink].set(flat_val, mode="drop")
    return out[:-1]
