"""Multi-chip hybrid partitioning: the paper's key router as a collective.

On the FPGA, vertical partitioning splits the tree into subtrees that live in
disjoint BRAM groups, and a routing network moves keys from the register
layer to the right subtree.  On a TPU pod the disjoint memories are *chips*:

  * the register layer (top ``log2(M)`` levels, a few KiB) is REPLICATED on
    every chip -- exactly the port-less register file;
  * subtree ``s`` lives in chip ``s``'s HBM (sharded over the ``model`` axis);
  * the routing network is an ``all_to_all``: after the local register-layer
    descent, each chip posts (dest -> key) buffers built by the paper's
    queue mapping, and the collective delivers each subtree its keys;
  * results ride the inverse all_to_all back to the requesting chip.

Tree *duplication* (DupN) is replication over the ``data``/``pod`` axes: each
replica group serves its own query stream -- plain data parallelism, included
here for completeness via ``dup_lookup``.

Buffer capacity is the collective-bytes lever (§Perf): capacity == local
batch is stall-free but sends B x M keys; smaller capacities send less and
handle overflow with an extra "stall round", faithfully mirroring the
paper's throughput/buffer-size trade-off.

Every pipeline phase here (route / dispatch / descend / combine) comes
from ``core/plans.py``, so this module only contributes the collectives
and the sharding (DESIGN.md §4).  Since §8 this is the ONE driver that
still composes the phases: the single-chip engine runs the whole hybrid
pipeline inside the forest kernel, but here dispatch IS a pair of
``all_to_all`` collectives, which no kernel body can absorb.

The entry point is ``make_distributed_query`` -- the same ``query(op, ...)``
contract as ``BSTEngine.query`` (DESIGN.md §6): the ordered descent runs
sharded (the full ``OrderedResult`` rides the return ``all_to_all`` as one
packed collective), so ONE compiled program serves every op -- lookups here
deliberately share the ordered datapath (+5 int32 lanes of return payload)
rather than compile a second membership-only program per mesh.  The per-op
epilogues are the plans-layer functions, and
range_scan's sorted-view gather reads the host snapshot (the bounded ``k``
columns are tiny next to the descent traffic).  ``make_distributed_lookup``
and ``make_dup_lookup`` remain as membership shorthands.

The live write path (DESIGN.md §7) extends the contract: ``run(op, ...,
delta=...)`` takes a ``core.delta.DeltaBuffer`` of pending
upserts/tombstones.  Like the register layer, the buffer is small and
REPLICATED on every chip; since DESIGN.md §9 its resolution runs INSIDE
the shard_map program -- each chip folds the replicated operands into its
local slice of the packed ``OrderedResult`` in the same compiled sharded
program as the collectives, so writes cost no extra collective and no
driver-level jnp twin remains.  The ordered epilogues then switch to rank
selection over the merged key set.  Compaction swaps the snapshot exactly
like a bulk rebuild.

``make_sharded_query`` is the serving-facing factory (DESIGN.md §9): one
strategy name -- hrz / dup / hyb, the same vocabulary as ``EngineConfig``
-- picks the mesh layout (``plans.mesh_axis_for_strategy``), the routing
pattern and the buffer capacities, and returns the same ``run(op, ...)``
contract, so ``BSTServer`` shards by flipping a constructor argument.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.analysis import invariants
from repro.core import delta as delta_lib
from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core.tree import TreeData

# The delta buffer rides every sharded program as four REPLICATED flat
# operands (DESIGN.md §9).  One constant serves both shard_map builders and
# the static contract checker, so the replication layout cannot drift.
DELTA_IN_SPECS = (P(),) * invariants.DELTA_OPERANDS

# Deriving the kernel operands from a DeltaBuffer compares against the host
# sentinel scalar; jitted so steady-state chunks replay a cached program
# instead of re-shipping the constant to device on every call.
_delta_operands = jax.jit(delta_lib.operands)


def stored_nodes_per_device(*arrays) -> int:
    """MEASURED stored key slots on the fullest device, from the arrays'
    actual shard layout (not a formula): the per-device memory figure the
    bench gate compares against single-chip (DESIGN.md §9).  A sharding
    regression that silently replicated a partitioned operand shows up
    here as an M-fold jump.
    """
    per: dict = {}
    for a in arrays:
        for s in a.addressable_shards:
            per[s.device] = per.get(s.device, 0) + int(np.prod(s.data.shape))
    return max(per.values()) if per else 0


def shard_subtrees(
    tree: TreeData, mesh: Mesh, axis: str
) -> Tuple[jax.Array, jax.Array, int, int]:
    """Vertical-partition the tree across ``axis``: (M, sub_n) arrays."""
    M = mesh.shape[axis]
    split_level = invariants.check_power_of_two(M, f"mesh axis {axis} size")
    if split_level > tree.height:
        raise ValueError("tree shallower than the mesh axis")
    idx = tree_lib.all_subtree_gather_indices(tree.height, split_level)
    sub_keys = jnp.asarray(np.asarray(tree.keys)[idx])
    sub_vals = jnp.asarray(np.asarray(tree.values)[idx])
    sharding = NamedSharding(mesh, P(axis, None))
    sub_keys = jax.device_put(sub_keys, sharding)
    sub_vals = jax.device_put(sub_vals, sharding)
    return sub_keys, sub_vals, split_level, tree.height - split_level


def _make_query_runner(
    descend, tree: TreeData, rank_to_bfs: jax.Array, lookup=None
):
    """Wrap a sharded ordered-descent into the ``run(op, ...)`` contract.

    One implementation of the op dispatch (operand validation, lo||hi
    concat/split, per-op epilogues from core/plans) shared by the
    all_to_all and data-parallel engines, so the contract cannot drift
    between them or from ``BSTEngine.query``.  ``delta`` (a replicated
    ``core.delta.DeltaBuffer``) rides the sharded program as four flat
    operands: ``descend(both, d_ops)`` folds the buffer ON-DEVICE inside
    the shard_map body (DESIGN.md §9), and this wrapper only switches the
    epilogues to their delta-aware twins (DESIGN.md §7).

    ``lookup`` is an optional membership fast path: a 2-output
    ``(queries, d_ops) -> (values, found)`` sharded program (the engine's
    own §6 rule -- the hot lookup path pays nothing for the ordered
    datapath).  Without it lookups ride the ordered descent and take its
    value/found lanes.
    """
    sorted_cache: list = []  # built on the first delta call only

    def _sorted_view():
        if not sorted_cache:
            sorted_cache.append((tree.keys[rank_to_bfs], tree.values[rank_to_bfs]))
        return sorted_cache[0]

    # Per-op epilogues, jitted once per (op, k, delta?) so steady-state
    # chunks replay cached programs: run eagerly, every sentinel/arange/
    # n_real constant they mix with device results would ride host->device
    # again on EVERY chunk (the retrace/transfer gate fails exactly there).
    # The snapshot constants (sorted view, rank map) fold at compile time.
    epilogues: dict = {}

    def _epilogue(op: str, k: int, with_delta: bool):
        key = (op, k if op == "range_scan" else None, with_delta)
        if key not in epilogues:
            if with_delta:
                # Materialized OUTSIDE the trace: caching a gather computed
                # under jit would leak tracers into sorted_cache.
                sorted_keys, sorted_values = _sorted_view()
            if op in plans_lib.RANGE_OPS:
                def _split(res):
                    # lo||hi concatenated descent (DESIGN.md §6) splits
                    # back here, inside the jitted epilogue: an eager
                    # slice of the sharded result is a per-chunk transfer.
                    B = res.value.shape[0] // 2
                    return (
                        plans_lib.OrderedResult(*(f[:B] for f in res)),
                        plans_lib.OrderedResult(*(f[B:] for f in res)),
                    )

                if with_delta:
                    def fn(res, delta):
                        r_lo, r_hi = _split(res)
                        return delta_lib.range_epilogue(
                            op, sorted_keys, sorted_values, tree.n_real,
                            delta, r_lo, r_hi, k=k,
                        )
                else:
                    def fn(res):
                        r_lo, r_hi = _split(res)
                        return plans_lib.range_epilogue(
                            op, tree, rank_to_bfs, r_lo, r_hi, k=k
                        )
            elif with_delta:
                def fn(q, res, delta):
                    return delta_lib.point_epilogue(
                        op, q, res, sorted_keys, sorted_values, tree.n_real,
                        delta,
                    )
            else:
                def fn(q, res):
                    return plans_lib.point_epilogue(op, q, res)
            epilogues[key] = jax.jit(fn)
        return epilogues[key]

    def run(op: str, queries, queries_hi=None, *, k: int = 8, delta=None):
        plans_lib.validate_op(op, queries_hi is not None)
        d_ops = None if delta is None else _delta_operands(delta)
        if op == "lookup" and lookup is not None:
            # delta-hit > tombstone > tree-hit resolves in-program, so the
            # membership columns come back final either way.
            return lookup(jnp.asarray(queries, jnp.int32), d_ops)
        if op in plans_lib.RANGE_OPS:
            lo = jnp.asarray(queries, jnp.int32)
            hi = jnp.asarray(queries_hi, jnp.int32)
            both = jnp.concatenate([lo, hi])
            res = descend(both, d_ops)
            epi = _epilogue(op, k, delta is not None)
            return epi(res, delta) if delta is not None else epi(res)
        q = jnp.asarray(queries, jnp.int32)
        res = descend(q, d_ops)
        epi = _epilogue(op, k, delta is not None)
        return epi(q, res, delta) if delta is not None else epi(q, res)

    return run


def make_distributed_query(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "model",
    capacity: Optional[int] = None,
    stall_rounds: int = 1,
    use_kernel: bool = False,
    interpret: bool = True,
    capacity_frac: Optional[float] = None,
):
    """Build a jitted distributed ``query(op, ...)`` over ``axis``.

    Returns ``run(op, queries, queries_hi=None, *, k=8)`` with the same
    per-op contract as ``BSTEngine.query`` (DESIGN.md §6).  Query batches
    are (B_global,) sharded over ``axis``; results come back with the same
    sharding (range_scan's gathered columns are replicated host arrays).

    ``capacity`` is the per-(src,dst) buffer depth; None means stall-free
    (capacity = local batch).  ``capacity_frac`` instead sizes the depth
    per TRACE as the local batch's fair share scaled by the fraction
    (``ceil(B_local / M * frac)``), so the concatenated ``lo || hi``
    range traces (2x the lanes) keep the same relative slack as point
    traces instead of silently halving it.  ``stall_rounds`` extra rounds
    re-dispatch overflowed keys (paper: frontend stall while buffers
    drain); keys still pending afterwards ride one final stall-free drain
    round, so every result is exact -- capacity/stall_rounds trade
    collective bytes for rounds, never correctness.  ``use_kernel=True``
    routes each chip's local subtree descent through the forest-batched
    Pallas kernel.
    """
    if capacity is not None and capacity_frac is not None:
        raise ValueError("pass capacity OR capacity_frac, not both")
    M = mesh.shape[axis]
    sub_keys, sub_vals, split_level, sub_height = shard_subtrees(tree, mesh, axis)
    reg_n = (1 << max(split_level, 1)) - 1
    reg_keys = jax.device_put(tree.keys[:reg_n], NamedSharding(mesh, P()))
    reg_vals = jax.device_put(tree.values[:reg_n], NamedSharding(mesh, P()))
    rank_to_bfs = jnp.asarray(tree_lib.rank_to_bfs_indices(tree.height))

    def _one_round(queries, dest, active, sub_k, sub_v, cap):
        """dispatch -> all_to_all -> local ordered descent -> all_to_all back."""
        dplan = plans_lib.dispatch_phase("queue", dest, M, cap, active=active)
        send_q, send_live = plans_lib.gather_phase(queries, dplan)
        # (M, C): row d goes to chip d; receive row s = keys from chip s.
        recv_q = jax.lax.all_to_all(send_q, axis, 0, 0, tiled=False)
        recv_live = jax.lax.all_to_all(
            send_live.astype(jnp.int32), axis, 0, 0, tiled=False
        )
        sub = plans_lib.descend_phase_ordered(
            sub_k,
            sub_v,
            sub_height,
            recv_q.reshape(1, -1),
            (recv_live.reshape(-1) != 0)[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
        )
        packed = plans_lib.pack_ordered(
            plans_lib.OrderedResult(*(f[0].reshape(M, cap) for f in sub))
        )
        back = jax.lax.all_to_all(packed, axis, 0, 0, tiled=False)
        got = plans_lib.combine_phase_ordered(
            plans_lib.unpack_ordered(back), dplan, queries.shape[0]
        )
        return got, dplan.overflow

    capped = capacity is not None or capacity_frac is not None

    def _query_local(queries, sub_k, sub_v, *d_ops):
        B = queries.shape[0]
        if capacity_frac is not None:
            # Sized per trace: the lo||hi range traces see 2x the lanes
            # and get 2x the depth, keeping the slack a real constant.
            cap = invariants.capacity_for_trace(B, M, capacity_frac)
        else:
            cap = capacity if capacity is not None else B
        dest, reg = plans_lib.route_phase_ordered(
            reg_keys, reg_vals, queries, split_level, tree.height
        )
        acc = tree_lib.init_ordered(B)
        pending = ~reg.found
        # Stall rounds: overflowed keys re-enter, buffers now empty.
        for _ in range(1 + (stall_rounds if capped else 0)):
            got, overflow = _one_round(queries, dest, pending, sub_k, sub_v, cap)
            acc = plans_lib.where_ordered(pending & ~overflow, got, acc)
            pending = overflow
        if capped:
            # Final drain at capacity == local batch: queue mapping cannot
            # overflow a depth-B buffer, so NO lane is left with a partial
            # ordered result (ranks/floors must be exact, not best-effort --
            # the FPGA frontend likewise stalls until every key is placed).
            # Guarded by a mesh-wide any() so the full-size round only runs
            # when some chip still has pending keys: that is what makes
            # capacity/stall_rounds a real bytes-vs-rounds trade, the small
            # rounds lowering the probability of ever paying this one.
            def drain(args):
                acc, pending = args
                got, _ = _one_round(queries, dest, pending, sub_k, sub_v, B)
                return plans_lib.where_ordered(pending, got, acc)

            any_pending = (
                jax.lax.pmax(pending.any().astype(jnp.int32), axis) > 0
            )
            acc = jax.lax.cond(any_pending, drain, lambda a: a[0], (acc, pending))
        res = plans_lib.merge_ordered(reg, acc)
        if d_ops:
            # On-device delta fold (DESIGN.md §9): the REPLICATED buffer
            # resolves against this chip's local query slice inside the
            # same compiled sharded program as the collectives -- after the
            # register merge, so register hits see overrides too.
            res = delta_lib.merge_ordered(
                res, *delta_lib.resolve_operands(d_ops, queries)
            )
        return tuple(res)

    # One compiled sharded program per write-path state: reads without a
    # buffer keep the 3-operand program; the delta variant threads the four
    # replicated buffer operands through the same shard_map body.
    programs = {}

    def _program(with_delta: bool):
        if with_delta not in programs:
            programs[with_delta] = jax.jit(
                shard_map(
                    _query_local,
                    mesh=mesh,
                    in_specs=(P(axis), P(axis, None), P(axis, None))
                    + (DELTA_IN_SPECS if with_delta else ()),
                    out_specs=tuple([P(axis)] * 7),
                    check=False,
                )
            )
        return programs[with_delta]

    def _descend(queries, d_ops=None) -> plans_lib.OrderedResult:
        q = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        extra = tuple(d_ops) if d_ops is not None else ()
        return plans_lib.OrderedResult(
            *_program(bool(extra))(q, sub_keys, sub_vals, *extra)
        )

    run = _make_query_runner(_descend, tree, rank_to_bfs)
    run.mesh = mesh
    run.capacity = capacity
    run.split_level = split_level
    run.device_nodes = stored_nodes_per_device(sub_keys, reg_keys)
    return run


def make_distributed_lookup(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "model",
    capacity: Optional[int] = None,
    stall_rounds: int = 1,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """Membership shorthand over ``make_distributed_query`` (kept API)."""
    query = make_distributed_query(
        tree,
        mesh,
        axis=axis,
        capacity=capacity,
        stall_rounds=stall_rounds,
        use_kernel=use_kernel,
        interpret=interpret,
    )

    def run(queries: jax.Array):
        return query("lookup", queries)

    run.mesh = query.mesh
    run.capacity = query.capacity
    run.split_level = query.split_level
    run.query = query
    return run


def make_dup_query(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "data",
    use_kernel: bool = False,
    interpret: bool = True,
):
    """DupN as data parallelism: replicate the tree, shard the query stream.

    Returns the same ``run(op, ...)`` contract as ``make_distributed_query``
    -- each replica group runs the full ordered descent on its slice, so
    every op is embarrassingly parallel here.  ``use_kernel=True`` lowers
    each replica's local descent through the forest-batched Pallas kernel;
    ``delta`` folds the replicated write buffer on-device per replica
    (DESIGN.md §9).  Lookups take a MEMBERSHIP fast-path program (the
    kernel's 2-output configuration, the engine's own §6 rule): with no
    collectives to share, the hot path pays nothing for the ordered
    datapath's extra tracking or return lanes.
    """
    keys = jax.device_put(tree.keys, NamedSharding(mesh, P()))
    vals = jax.device_put(tree.values, NamedSharding(mesh, P()))
    rank_to_bfs = jnp.asarray(tree_lib.rank_to_bfs_indices(tree.height))

    def _local(queries, k, v, *d_ops):
        res = plans_lib.descend_phase_ordered(
            k[None, :],
            v[None, :],
            tree.height,
            queries[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
        )
        res = plans_lib.OrderedResult(*(f[0] for f in res))
        if d_ops:
            res = delta_lib.merge_ordered(
                res, *delta_lib.resolve_operands(d_ops, queries)
            )
        return tuple(res)

    def _local_lookup(queries, k, v, *d_ops):
        val, found = plans_lib.descend_phase(
            k[None, :],
            v[None, :],
            tree.height,
            queries[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
        )
        val, found = val[0], found[0]
        if d_ops:
            hit, dead, d_val, _ = delta_lib.resolve_operands(d_ops, queries)
            val, found = delta_lib.merge_lookup(val, found, hit, dead, d_val)
        return val, found

    programs = {}

    def _program(body, n_out: int, with_delta: bool):
        key = (body.__name__, with_delta)
        if key not in programs:
            programs[key] = jax.jit(
                shard_map(
                    body,
                    mesh=mesh,
                    in_specs=(P(axis), P(), P())
                    + (DELTA_IN_SPECS if with_delta else ()),
                    out_specs=tuple([P(axis)] * n_out),
                    check=False,
                )
            )
        return programs[key]

    def _call(body, n_out, queries, d_ops):
        q = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        extra = tuple(d_ops) if d_ops is not None else ()
        return _program(body, n_out, bool(extra))(q, keys, vals, *extra)

    def _descend(queries, d_ops=None) -> plans_lib.OrderedResult:
        return plans_lib.OrderedResult(*_call(_local, 7, queries, d_ops))

    def _lookup(queries, d_ops=None):
        return _call(_local_lookup, 2, queries, d_ops)

    run = _make_query_runner(_descend, tree, rank_to_bfs, lookup=_lookup)
    run.mesh = mesh
    run.device_nodes = stored_nodes_per_device(keys)
    return run


def make_dup_lookup(tree: TreeData, mesh: Mesh, axis: str = "data"):
    """Membership shorthand over ``make_dup_query`` (kept API)."""
    query = make_dup_query(tree, mesh, axis=axis)

    def run(queries: jax.Array):
        return query("lookup", queries)

    run.mesh = query.mesh
    run.query = query
    return run


# ------------------------------------------------------------ sharded serving
def make_serving_mesh(strategy: str, devices=None) -> Mesh:
    """A 1-D mesh over ``devices`` named for the strategy's shard axis.

    The serving layer shards over ONE axis (DESIGN.md §9): the batch for
    dup, the tree for hrz/hyb.  ``plans.mesh_axis_for_strategy`` picks the
    name, so a mesh built here always satisfies ``make_sharded_query``.
    """
    axis = plans_lib.mesh_axis_for_strategy(strategy)
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.asarray(devs), (axis,))


def make_sharded_query(
    tree: TreeData,
    mesh: Mesh,
    strategy: str,
    *,
    buffer_slack: float = 2.0,
    stall_rounds: int = 1,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """The serving-facing sharded factory (DESIGN.md §9).

    One strategy name -- the same hrz / dup / hyb vocabulary as
    ``EngineConfig`` -- picks the whole mesh layout:

      * ``hrz``: the tree vertically partitioned into per-device subtrees,
        chunks routed by the STALL-FREE all_to_all (capacity == local
        batch -- one round, maximal collective bytes);
      * ``dup``: the tree replicated, the chunk split over the axis (pure
        data parallelism, no routing traffic);
      * ``hyb``: subtree-sharded forest + replicated register layer with
        the paper's queue-capped dispatch buffers: per-(src,dst) capacity
        sized PER TRACE as the local batch's fair share scaled by
        ``buffer_slack`` (so range ops' doubled lo||hi lanes keep the same
        relative slack) plus ``stall_rounds`` -- collective bytes traded
        for rounds, correctness guaranteed by the final drain round.

    Returns the ``run(op, queries, queries_hi=None, *, k=8, delta=None)``
    contract of ``make_distributed_query``; ``delta`` folds on-device
    inside the sharded program.  The caller must keep global batches
    divisible by the axis size (``BSTServer`` pads its fixed-shape chunks
    and enforces divisibility at construction).
    """
    axis = plans_lib.mesh_axis_for_strategy(strategy)
    if axis not in mesh.axis_names:
        raise ValueError(
            f"strategy {strategy!r} shards over mesh axis {axis!r}, but the "
            f"mesh has {mesh.axis_names} -- build one with make_serving_mesh"
        )
    if strategy == "dup":
        run = make_dup_query(
            tree, mesh, axis=axis, use_kernel=use_kernel, interpret=interpret
        )
        run.capacity_frac = None
    else:
        frac = buffer_slack if strategy == "hyb" else None
        run = make_distributed_query(
            tree,
            mesh,
            axis=axis,
            capacity_frac=frac,  # hrz: None -> stall-free routing
            stall_rounds=stall_rounds,
            use_kernel=use_kernel,
            interpret=interpret,
        )
        run.capacity_frac = frac
    run.strategy = strategy
    run.axis = axis
    run.n_shards = mesh.shape[axis]
    return run
