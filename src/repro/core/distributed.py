"""Multi-chip hybrid partitioning: the paper's key router as a collective.

On the FPGA, vertical partitioning splits the tree into subtrees that live in
disjoint BRAM groups, and a routing network moves keys from the register
layer to the right subtree.  On a TPU pod the disjoint memories are *chips*:

  * the register layer (top ``log2(M)`` levels, a few KiB) is REPLICATED on
    every chip -- exactly the port-less register file;
  * subtree ``s`` lives in chip ``s``'s HBM (sharded over the ``model`` axis);
  * the routing network is an ``all_to_all``: after the local register-layer
    descent, each chip posts (dest -> key) buffers built by the paper's
    queue mapping, and the collective delivers each subtree its keys;
  * results ride the inverse all_to_all back to the requesting chip.

Tree *duplication* (DupN) is replication over the ``data``/``pod`` axes: each
replica group serves its own query stream -- plain data parallelism, included
here for completeness via ``dup_lookup``.

Buffer capacity is the collective-bytes lever (§Perf): capacity == local
batch is stall-free but sends B x M keys; smaller capacities send less and
handle overflow with an extra "stall round", faithfully mirroring the
paper's throughput/buffer-size trade-off.

Every pipeline phase here (route / dispatch / descend / combine) is the
SAME implementation the single-chip ``BSTEngine`` runs -- imported from
``core/plans.py`` -- so this module only contributes the collectives and
the sharding (DESIGN.md §4).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core.tree import TreeData


def shard_subtrees(
    tree: TreeData, mesh: Mesh, axis: str
) -> Tuple[jax.Array, jax.Array, int, int]:
    """Vertical-partition the tree across ``axis``: (M, sub_n) arrays."""
    M = mesh.shape[axis]
    split_level = int(math.log2(M))
    if (1 << split_level) != M:
        raise ValueError(f"mesh axis {axis} size {M} must be a power of two")
    if split_level > tree.height:
        raise ValueError("tree shallower than the mesh axis")
    idx = tree_lib.all_subtree_gather_indices(tree.height, split_level)
    sub_keys = jnp.asarray(np.asarray(tree.keys)[idx])
    sub_vals = jnp.asarray(np.asarray(tree.values)[idx])
    sharding = NamedSharding(mesh, P(axis, None))
    sub_keys = jax.device_put(sub_keys, sharding)
    sub_vals = jax.device_put(sub_vals, sharding)
    return sub_keys, sub_vals, split_level, tree.height - split_level


def make_distributed_lookup(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "model",
    capacity: Optional[int] = None,
    stall_rounds: int = 1,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """Build a jitted distributed lookup over ``axis``.

    queries: (B_global,) sharded over ``axis``; returns (values, found) with
    the same sharding.  ``capacity`` is the per-(src,dst) buffer depth; None
    means stall-free (capacity = local batch).  ``stall_rounds`` extra rounds
    re-dispatch overflowed keys (paper: frontend stall while buffers drain).
    ``use_kernel=True`` routes each chip's local subtree descent through the
    forest-batched Pallas kernel.
    """
    M = mesh.shape[axis]
    sub_keys, sub_vals, split_level, sub_height = shard_subtrees(tree, mesh, axis)
    reg_n = (1 << max(split_level, 1)) - 1
    reg_keys = jax.device_put(tree.keys[:reg_n], NamedSharding(mesh, P()))
    reg_vals = jax.device_put(tree.values[:reg_n], NamedSharding(mesh, P()))

    def _one_round(queries, dest, active, sub_k, sub_v, cap):
        """dispatch -> all_to_all -> local subtree descent -> all_to_all back."""
        dplan = plans_lib.dispatch_phase("queue", dest, M, cap, active=active)
        send_q, send_live = plans_lib.gather_phase(queries, dplan)
        # (M, C): row d goes to chip d; receive row s = keys from chip s.
        recv_q = jax.lax.all_to_all(send_q, axis, 0, 0, tiled=False)
        recv_live = jax.lax.all_to_all(
            send_live.astype(jnp.int32), axis, 0, 0, tiled=False
        )
        vals, found = plans_lib.descend_phase(
            sub_k,
            sub_v,
            sub_height,
            recv_q.reshape(1, -1),
            (recv_live.reshape(-1) != 0)[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
        )
        back_v = jax.lax.all_to_all(vals[0].reshape(M, cap), axis, 0, 0, tiled=False)
        back_f = (
            jax.lax.all_to_all(
                found[0].astype(jnp.int32).reshape(M, cap), axis, 0, 0, tiled=False
            )
            != 0
        )
        got_v, got_f = plans_lib.combine_phase(back_v, back_f, dplan, queries.shape[0])
        return got_v, got_f, dplan.overflow

    def _lookup_local(queries, sub_k, sub_v):
        B = queries.shape[0]
        cap = capacity if capacity is not None else B
        dest, val, found = plans_lib.route_phase(
            reg_keys, reg_vals, queries, split_level
        )
        active = ~found
        got_v, got_f, overflow = _one_round(queries, dest, active, sub_k, sub_v, cap)
        val = jnp.where(active & ~overflow, got_v, val)
        found = found | got_f
        # Stall rounds: overflowed keys re-enter, buffers now empty.
        for _ in range(stall_rounds if capacity is not None else 0):
            got_v, got_f, overflow = _one_round(
                queries, dest, overflow, sub_k, sub_v, cap
            )
            val = jnp.where(got_f, got_v, val)
            found = found | got_f
        return val, found

    lookup = jax.jit(
        shard_map(
            _lookup_local,
            mesh=mesh,
            in_specs=(P(axis), P(axis, None), P(axis, None)),
            out_specs=(P(axis), P(axis)),
            check=False,
        )
    )

    def run(queries: jax.Array):
        queries = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        return lookup(queries, sub_keys, sub_vals)

    run.mesh = mesh
    run.capacity = capacity
    run.split_level = split_level
    return run


def make_dup_lookup(tree: TreeData, mesh: Mesh, axis: str = "data"):
    """DupN as data parallelism: replicate the tree, shard the query stream."""
    keys = jax.device_put(tree.keys, NamedSharding(mesh, P()))
    vals = jax.device_put(tree.values, NamedSharding(mesh, P()))

    def _local(queries, k, v):
        vals_, found_ = plans_lib.descend_phase(
            k[None, :], v[None, :], tree.height, queries[None, :]
        )
        return vals_[0], found_[0]

    lookup = jax.jit(
        shard_map(
            _local,
            mesh=mesh,
            in_specs=(P(axis), P(), P()),
            out_specs=(P(axis), P(axis)),
            check=False,
        )
    )

    def run(queries: jax.Array):
        queries = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        return lookup(queries, keys, vals)

    run.mesh = mesh
    return run
