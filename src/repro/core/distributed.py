"""Multi-chip hybrid partitioning: the paper's key router as a collective.

On the FPGA, vertical partitioning splits the tree into subtrees that live in
disjoint BRAM groups, and a routing network moves keys from the register
layer to the right subtree.  On a TPU pod the disjoint memories are *chips*:

  * the register layer (top ``log2(M)`` levels, a few KiB) is REPLICATED on
    every chip -- exactly the port-less register file;
  * subtree ``s`` lives in chip ``s``'s HBM (sharded over the ``model`` axis);
  * the routing network is an ``all_to_all``: after the local register-layer
    descent, each chip posts (dest -> key) buffers built by the paper's
    queue mapping, and the collective delivers each subtree its keys;
  * results ride the inverse all_to_all back to the requesting chip.

Tree *duplication* (DupN) is replication over the ``data``/``pod`` axes: each
replica group serves its own query stream -- plain data parallelism, included
here for completeness via ``dup_lookup``.

Buffer capacity is the collective-bytes lever (§Perf): capacity == local
batch is stall-free but sends B x M keys; smaller capacities send less and
handle overflow with an extra "stall round", faithfully mirroring the
paper's throughput/buffer-size trade-off.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import buffers as buf
from repro.core import tree as tree_lib
from repro.core.tree import TreeData


def shard_subtrees(
    tree: TreeData, mesh: Mesh, axis: str
) -> Tuple[jax.Array, jax.Array, int, int]:
    """Vertical-partition the tree across ``axis``: (M, sub_n) arrays."""
    M = mesh.shape[axis]
    split_level = int(math.log2(M))
    if (1 << split_level) != M:
        raise ValueError(f"mesh axis {axis} size {M} must be a power of two")
    if split_level > tree.height:
        raise ValueError("tree shallower than the mesh axis")
    idx = tree_lib.all_subtree_gather_indices(tree.height, split_level)
    sub_keys = jnp.asarray(np.asarray(tree.keys)[idx])
    sub_vals = jnp.asarray(np.asarray(tree.values)[idx])
    sharding = NamedSharding(mesh, P(axis, None))
    sub_keys = jax.device_put(sub_keys, sharding)
    sub_vals = jax.device_put(sub_vals, sharding)
    return sub_keys, sub_vals, split_level, tree.height - split_level


def make_distributed_lookup(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "model",
    capacity: Optional[int] = None,
    stall_rounds: int = 1,
):
    """Build a jitted distributed lookup over ``axis``.

    queries: (B_global,) sharded over ``axis``; returns (values, found) with
    the same sharding.  ``capacity`` is the per-(src,dst) buffer depth; None
    means stall-free (capacity = local batch).  ``stall_rounds`` extra rounds
    re-dispatch overflowed keys (paper: frontend stall while buffers drain).
    """
    M = mesh.shape[axis]
    sub_keys, sub_vals, split_level, sub_height = shard_subtrees(tree, mesh, axis)
    reg_keys, reg_vals = tree.register_layer(max(split_level, 1))
    reg_keys = jax.device_put(reg_keys, NamedSharding(mesh, P()))
    reg_vals = jax.device_put(reg_vals, NamedSharding(mesh, P()))
    reg_tree = TreeData(reg_keys, reg_vals, max(split_level, 1) - 1, int(reg_keys.shape[0]))

    def _route_local(queries):
        """Register-layer descent (replicated constants)."""
        if split_level == 0:
            B = queries.shape[0]
            return (
                jnp.zeros((B,), jnp.int32),
                jnp.full((B,), tree_lib.SENTINEL_VALUE, jnp.int32),
                jnp.zeros((B,), bool),
            )
        dest, val, found = tree_lib.register_layer_route(
            TreeData(reg_keys, reg_vals, split_level - 1, int(reg_keys.shape[0])),
            queries,
            split_level,
        )
        return dest, val, found

    def _one_round(queries, dest, active, sub_k, sub_v, cap):
        """dispatch -> all_to_all -> local subtree search -> all_to_all back."""
        plan = buf.queue_dispatch(dest, M, cap, active=active)
        send_q = buf.gather_from_buffers(queries, plan.buffers, fill_value=0)
        send_live = plan.buffers >= 0
        # (M, C): row d goes to chip d; receive row s = keys from chip s.
        recv_q = jax.lax.all_to_all(send_q, axis, 0, 0, tiled=False)
        recv_live = jax.lax.all_to_all(send_live.astype(jnp.int32), axis, 0, 0, tiled=False)
        flat_q = recv_q.reshape(-1)
        flat_live = recv_live.reshape(-1) != 0
        vals, found = tree_lib.subtree_search(
            sub_k[0], sub_v[0], sub_height, flat_q, flat_live
        )
        back_v = jax.lax.all_to_all(vals.reshape(M, cap), axis, 0, 0, tiled=False)
        back_f = (
            jax.lax.all_to_all(
                found.astype(jnp.int32).reshape(M, cap), axis, 0, 0, tiled=False
            )
            != 0
        )
        B = queries.shape[0]
        got_v = buf.combine_to_chunk(
            back_v, plan.buffers, B, fill_value=tree_lib.SENTINEL_VALUE
        )
        got_f = buf.combine_to_chunk(back_f, plan.buffers, B, fill_value=False)
        return got_v, got_f, plan.overflow

    def _lookup_local(queries, sub_k, sub_v):
        B = queries.shape[0]
        cap = capacity if capacity is not None else B
        dest, val, found = _route_local(queries)
        active = ~found
        got_v, got_f, overflow = _one_round(queries, dest, active, sub_k, sub_v, cap)
        val = jnp.where(active & ~overflow, got_v, val)
        found = found | got_f
        # Stall rounds: overflowed keys re-enter, buffers now empty.
        for _ in range(stall_rounds if capacity is not None else 0):
            got_v, got_f, overflow = _one_round(
                queries, dest, overflow, sub_k, sub_v, cap
            )
            val = jnp.where(got_f, got_v, val)
            found = found | got_f
        return val, found

    lookup = jax.jit(
        jax.shard_map(
            _lookup_local,
            mesh=mesh,
            in_specs=(P(axis), P(axis, None), P(axis, None)),
            out_specs=(P(axis), P(axis)),
            check_vma=False,
        )
    )

    def run(queries: jax.Array):
        queries = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        return lookup(queries, sub_keys, sub_vals)

    run.mesh = mesh
    run.capacity = capacity
    run.split_level = split_level
    return run


def make_dup_lookup(tree: TreeData, mesh: Mesh, axis: str = "data"):
    """DupN as data parallelism: replicate the tree, shard the query stream."""
    keys = jax.device_put(tree.keys, NamedSharding(mesh, P()))
    vals = jax.device_put(tree.values, NamedSharding(mesh, P()))
    rep = TreeData(keys, vals, tree.height, tree.n_real)

    def _local(queries):
        return tree_lib.search_reference(rep, queries)

    lookup = jax.jit(
        jax.shard_map(
            _local,
            mesh=mesh,
            in_specs=P(axis),
            out_specs=(P(axis), P(axis)),
            check_vma=False,
        )
    )

    def run(queries: jax.Array):
        queries = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        return lookup(queries)

    run.mesh = mesh
    return run
