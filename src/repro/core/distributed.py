"""Multi-chip hybrid partitioning: the paper's key router as a collective.

On the FPGA, vertical partitioning splits the tree into subtrees that live in
disjoint BRAM groups, and a routing network moves keys from the register
layer to the right subtree.  On a TPU pod the disjoint memories are *chips*:

  * the register layer (top ``log2(M)`` levels, a few KiB) is REPLICATED on
    every chip -- exactly the port-less register file;
  * subtree ``s`` lives in chip ``s``'s HBM (sharded over the ``model`` axis);
  * the routing network is an ``all_to_all``: after the local register-layer
    descent, each chip posts (dest -> key) buffers built by the paper's
    queue mapping, and the collective delivers each subtree its keys;
  * results ride the inverse all_to_all back to the requesting chip.

Tree *duplication* (DupN) is replication over the ``data``/``pod`` axes: each
replica group serves its own query stream -- plain data parallelism, included
here for completeness via ``dup_lookup``.

Buffer capacity is the collective-bytes lever (§Perf): capacity == local
batch is stall-free but sends B x M keys; smaller capacities send less and
handle overflow with an extra "stall round", faithfully mirroring the
paper's throughput/buffer-size trade-off.

Every pipeline phase here (route / dispatch / descend / combine) comes
from ``core/plans.py``, so this module only contributes the collectives
and the sharding (DESIGN.md §4).  Since §8 this is the ONE driver that
still composes the phases: the single-chip engine runs the whole hybrid
pipeline inside the forest kernel, but here dispatch IS a pair of
``all_to_all`` collectives, which no kernel body can absorb.

The entry point is ``make_distributed_query`` -- the same ``query(op, ...)``
contract as ``BSTEngine.query`` (DESIGN.md §6): the ordered descent runs
sharded (the full ``OrderedResult`` rides the return ``all_to_all`` as one
packed collective), so ONE compiled program serves every op -- lookups here
deliberately share the ordered datapath (+5 int32 lanes of return payload)
rather than compile a second membership-only program per mesh.  The per-op
epilogues are the plans-layer functions, and
range_scan's sorted-view gather reads the host snapshot (the bounded ``k``
columns are tiny next to the descent traffic).  ``make_distributed_lookup``
and ``make_dup_lookup`` remain as membership shorthands.

The live write path (DESIGN.md §7) extends the contract: ``run(op, ...,
delta=...)`` takes a ``core.delta.DeltaBuffer`` of pending
upserts/tombstones.  Like the register layer, the buffer is small and
REPLICATED on every chip; its resolution composes with the packed
``OrderedResult`` after the return collective (the kernel's jnp twin), and
the ordered epilogues switch to rank selection over the merged key set --
so every chip answers against snapshot + buffer without any extra
collective.  Compaction swaps the snapshot exactly like a bulk rebuild.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.core import delta as delta_lib
from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core.tree import TreeData


def shard_subtrees(
    tree: TreeData, mesh: Mesh, axis: str
) -> Tuple[jax.Array, jax.Array, int, int]:
    """Vertical-partition the tree across ``axis``: (M, sub_n) arrays."""
    M = mesh.shape[axis]
    split_level = int(math.log2(M))
    if (1 << split_level) != M:
        raise ValueError(f"mesh axis {axis} size {M} must be a power of two")
    if split_level > tree.height:
        raise ValueError("tree shallower than the mesh axis")
    idx = tree_lib.all_subtree_gather_indices(tree.height, split_level)
    sub_keys = jnp.asarray(np.asarray(tree.keys)[idx])
    sub_vals = jnp.asarray(np.asarray(tree.values)[idx])
    sharding = NamedSharding(mesh, P(axis, None))
    sub_keys = jax.device_put(sub_keys, sharding)
    sub_vals = jax.device_put(sub_vals, sharding)
    return sub_keys, sub_vals, split_level, tree.height - split_level


def _pack_ordered(res: plans_lib.OrderedResult, M: int, cap: int) -> jax.Array:
    """Stack a (1, M*cap) OrderedResult into one (M, cap, F) int32 image.

    The whole ordered payload rides the return routing network as ONE
    ``all_to_all`` instead of a collective per field.
    """
    return jnp.stack(
        [f[0].astype(jnp.int32).reshape(M, cap) for f in res], axis=-1
    )


def _unpack_ordered(packed: jax.Array) -> plans_lib.OrderedResult:
    # NamedTuple order on both sides keeps pack/unpack structurally tied.
    fields = tuple(packed[..., i] for i in range(packed.shape[-1]))
    res = plans_lib.OrderedResult(*fields)
    return res._replace(found=res.found != 0)


def _make_query_runner(descend, tree: TreeData, rank_to_bfs: jax.Array):
    """Wrap a sharded ordered-descent into the ``run(op, ...)`` contract.

    One implementation of the op dispatch (operand validation, lo||hi
    concat/split, per-op epilogues from core/plans) shared by the
    all_to_all and data-parallel engines, so the contract cannot drift
    between them or from ``BSTEngine.query``.  ``delta`` (a replicated
    ``core.delta.DeltaBuffer``) folds the pending write buffer into the
    descent results and switches the epilogues to their delta-aware twins
    (DESIGN.md §7) -- the collectives themselves are untouched.
    """
    sorted_cache: list = []  # built on the first delta call only

    def _sorted_view():
        if not sorted_cache:
            sorted_cache.append((tree.keys[rank_to_bfs], tree.values[rank_to_bfs]))
        return sorted_cache[0]

    def run(op: str, queries, queries_hi=None, *, k: int = 8, delta=None):
        plans_lib.validate_op(op, queries_hi is not None)
        if op in plans_lib.RANGE_OPS:
            lo = jnp.asarray(queries, jnp.int32)
            hi = jnp.asarray(queries_hi, jnp.int32)
            B = lo.shape[0]
            both = jnp.concatenate([lo, hi])
            res = descend(both)
            if delta is not None:
                res = delta_lib.merge_ordered(
                    res, *delta_lib.resolve(delta, both)
                )
            r_lo = plans_lib.OrderedResult(*(f[:B] for f in res))
            r_hi = plans_lib.OrderedResult(*(f[B:] for f in res))
            if delta is not None:
                sorted_keys, sorted_values = _sorted_view()
                return delta_lib.range_epilogue(
                    op, sorted_keys, sorted_values, tree.n_real, delta,
                    r_lo, r_hi, k=k,
                )
            return plans_lib.range_epilogue(op, tree, rank_to_bfs, r_lo, r_hi, k=k)
        q = jnp.asarray(queries, jnp.int32)
        res = descend(q)
        if delta is not None:
            sorted_keys, sorted_values = _sorted_view()
            res = delta_lib.merge_ordered(res, *delta_lib.resolve(delta, q))
            return delta_lib.point_epilogue(
                op, q, res, sorted_keys, sorted_values, tree.n_real, delta
            )
        return plans_lib.point_epilogue(op, q, res)

    return run


def make_distributed_query(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "model",
    capacity: Optional[int] = None,
    stall_rounds: int = 1,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """Build a jitted distributed ``query(op, ...)`` over ``axis``.

    Returns ``run(op, queries, queries_hi=None, *, k=8)`` with the same
    per-op contract as ``BSTEngine.query`` (DESIGN.md §6).  Query batches
    are (B_global,) sharded over ``axis``; results come back with the same
    sharding (range_scan's gathered columns are replicated host arrays).

    ``capacity`` is the per-(src,dst) buffer depth; None means stall-free
    (capacity = local batch).  ``stall_rounds`` extra rounds re-dispatch
    overflowed keys (paper: frontend stall while buffers drain); keys still
    pending afterwards ride one final stall-free drain round, so every
    result is exact -- capacity/stall_rounds trade collective bytes for
    rounds, never correctness.  ``use_kernel=True`` routes each chip's local
    subtree descent through the forest-batched Pallas kernel.
    """
    M = mesh.shape[axis]
    sub_keys, sub_vals, split_level, sub_height = shard_subtrees(tree, mesh, axis)
    reg_n = (1 << max(split_level, 1)) - 1
    reg_keys = jax.device_put(tree.keys[:reg_n], NamedSharding(mesh, P()))
    reg_vals = jax.device_put(tree.values[:reg_n], NamedSharding(mesh, P()))
    rank_to_bfs = jnp.asarray(tree_lib.rank_to_bfs_indices(tree.height))

    def _one_round(queries, dest, active, sub_k, sub_v, cap):
        """dispatch -> all_to_all -> local ordered descent -> all_to_all back."""
        dplan = plans_lib.dispatch_phase("queue", dest, M, cap, active=active)
        send_q, send_live = plans_lib.gather_phase(queries, dplan)
        # (M, C): row d goes to chip d; receive row s = keys from chip s.
        recv_q = jax.lax.all_to_all(send_q, axis, 0, 0, tiled=False)
        recv_live = jax.lax.all_to_all(
            send_live.astype(jnp.int32), axis, 0, 0, tiled=False
        )
        sub = plans_lib.descend_phase_ordered(
            sub_k,
            sub_v,
            sub_height,
            recv_q.reshape(1, -1),
            (recv_live.reshape(-1) != 0)[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
        )
        back = jax.lax.all_to_all(
            _pack_ordered(sub, M, cap), axis, 0, 0, tiled=False
        )
        got = plans_lib.combine_phase_ordered(
            _unpack_ordered(back), dplan, queries.shape[0]
        )
        return got, dplan.overflow

    def _query_local(queries, sub_k, sub_v):
        B = queries.shape[0]
        cap = capacity if capacity is not None else B
        dest, reg = plans_lib.route_phase_ordered(
            reg_keys, reg_vals, queries, split_level, tree.height
        )
        acc = tree_lib.init_ordered(B)
        pending = ~reg.found
        # Stall rounds: overflowed keys re-enter, buffers now empty.
        for _ in range(1 + (stall_rounds if capacity is not None else 0)):
            got, overflow = _one_round(queries, dest, pending, sub_k, sub_v, cap)
            acc = plans_lib.where_ordered(pending & ~overflow, got, acc)
            pending = overflow
        if capacity is not None:
            # Final drain at capacity == local batch: queue mapping cannot
            # overflow a depth-B buffer, so NO lane is left with a partial
            # ordered result (ranks/floors must be exact, not best-effort --
            # the FPGA frontend likewise stalls until every key is placed).
            # Guarded by a mesh-wide any() so the full-size round only runs
            # when some chip still has pending keys: that is what makes
            # capacity/stall_rounds a real bytes-vs-rounds trade, the small
            # rounds lowering the probability of ever paying this one.
            def drain(args):
                acc, pending = args
                got, _ = _one_round(queries, dest, pending, sub_k, sub_v, B)
                return plans_lib.where_ordered(pending, got, acc)

            any_pending = (
                jax.lax.pmax(pending.any().astype(jnp.int32), axis) > 0
            )
            acc = jax.lax.cond(any_pending, drain, lambda a: a[0], (acc, pending))
        return tuple(plans_lib.merge_ordered(reg, acc))

    ordered = jax.jit(
        shard_map(
            _query_local,
            mesh=mesh,
            in_specs=(P(axis), P(axis, None), P(axis, None)),
            out_specs=tuple([P(axis)] * 7),
            check=False,
        )
    )

    def _descend(queries: np.ndarray) -> plans_lib.OrderedResult:
        q = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        return plans_lib.OrderedResult(*ordered(q, sub_keys, sub_vals))

    run = _make_query_runner(_descend, tree, rank_to_bfs)
    run.mesh = mesh
    run.capacity = capacity
    run.split_level = split_level
    return run


def make_distributed_lookup(
    tree: TreeData,
    mesh: Mesh,
    axis: str = "model",
    capacity: Optional[int] = None,
    stall_rounds: int = 1,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """Membership shorthand over ``make_distributed_query`` (kept API)."""
    query = make_distributed_query(
        tree,
        mesh,
        axis=axis,
        capacity=capacity,
        stall_rounds=stall_rounds,
        use_kernel=use_kernel,
        interpret=interpret,
    )

    def run(queries: jax.Array):
        return query("lookup", queries)

    run.mesh = query.mesh
    run.capacity = query.capacity
    run.split_level = query.split_level
    run.query = query
    return run


def make_dup_query(tree: TreeData, mesh: Mesh, axis: str = "data"):
    """DupN as data parallelism: replicate the tree, shard the query stream.

    Returns the same ``run(op, ...)`` contract as ``make_distributed_query``
    -- each replica group runs the full ordered descent on its slice, so
    every op is embarrassingly parallel here.
    """
    keys = jax.device_put(tree.keys, NamedSharding(mesh, P()))
    vals = jax.device_put(tree.values, NamedSharding(mesh, P()))
    rank_to_bfs = jnp.asarray(tree_lib.rank_to_bfs_indices(tree.height))

    def _local(queries, k, v):
        res = plans_lib.descend_phase_ordered(
            k[None, :], v[None, :], tree.height, queries[None, :]
        )
        return tuple(f[0] for f in res)

    ordered = jax.jit(
        shard_map(
            _local,
            mesh=mesh,
            in_specs=(P(axis), P(), P()),
            out_specs=tuple([P(axis)] * 7),
            check=False,
        )
    )

    def _descend(queries) -> plans_lib.OrderedResult:
        q = jax.device_put(
            jnp.asarray(queries, jnp.int32), NamedSharding(mesh, P(axis))
        )
        return plans_lib.OrderedResult(*ordered(q, keys, vals))

    run = _make_query_runner(_descend, tree, rank_to_bfs)
    run.mesh = mesh
    return run


def make_dup_lookup(tree: TreeData, mesh: Mesh, axis: str = "data"):
    """Membership shorthand over ``make_dup_query`` (kept API)."""
    query = make_dup_query(tree, mesh, axis=axis)

    def run(queries: jax.Array):
        return query("lookup", queries)

    run.mesh = query.mesh
    run.query = query
    return run
