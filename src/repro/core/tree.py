"""Complete-BST construction and level-major (Eytzinger/BFS) layout.

The paper stores 32-bit key / 32-bit value pairs of a *complete* binary tree
level-by-level in separate BRAM partitions.  The software analogue of "one
BRAM partition per level" is the BFS (a.k.a. Eytzinger) layout: node ``i``'s
children are ``2i+1`` / ``2i+2`` and level ``l`` occupies the contiguous
slice ``[2^l - 1, 2^{l+1} - 1)``.  Each descent step then touches exactly one
contiguous region -- the property the FPGA design builds its level pipeline
on, and the property that lets the forest-batched Pallas kernel keep each
whole tree in ONE flat level-major VMEM operand (kernels/bst_search.py).

We work with *perfect* trees (n = 2^{H+1} - 1 nodes); arbitrary sorted inputs
are padded with a +inf sentinel, matching the paper's complete-tree setting
("the throughput will not change when the type of tree changes during a
stream of infinite keys").
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel key for padding to a perfect tree.  int32 max keeps compare
# semantics intact for any real int32 key strictly below it.
SENTINEL_KEY = np.int32(np.iinfo(np.int32).max)
SENTINEL_VALUE = np.int32(-1)

# Ordered-query sentinels (DESIGN.md §6): the descent tracks the last
# right-turn ancestor (largest stored key < q) and last left-turn ancestor
# (smallest stored key > q).  "No such ancestor" self-encodes as the identity
# of the max/min tracking -- int32 min for predecessors, int32 max for
# successors (the latter coincides with SENTINEL_KEY: a sentinel successor
# IS "no real successor", since sentinels pad above every real key).
NO_PRED_KEY = np.int32(np.iinfo(np.int32).min)
NO_SUCC_KEY = SENTINEL_KEY


class OrderedResult(NamedTuple):
    """Per-query outputs of one ordered compare-descend pass (DESIGN.md §6).

    value/found: the exact-match payload (SENTINEL_VALUE when absent).
    pred_key/pred_value: deepest right-turn ancestor == largest stored key
        strictly below the query (NO_PRED_KEY/SENTINEL_VALUE when none).
    succ_key/succ_value: deepest left-turn ancestor == smallest stored key
        strictly above the query (NO_SUCC_KEY/SENTINEL_VALUE when none).
    rank: number of stored keys strictly below the query -- the rank
        boundary that range_count / range_scan arithmetic builds on.
    """

    value: jax.Array
    found: jax.Array
    pred_key: jax.Array
    pred_value: jax.Array
    succ_key: jax.Array
    succ_value: jax.Array
    rank: jax.Array


def level_offset(level: int) -> int:
    """First BFS index of ``level`` (the start of its "BRAM partition")."""
    return (1 << level) - 1


def level_size(level: int) -> int:
    return 1 << level


def height_for(n_keys: int) -> int:
    """Height H of the smallest perfect tree holding ``n_keys`` nodes."""
    h = 0
    while ((1 << (h + 1)) - 1) < n_keys:
        h += 1
    return h


@dataclasses.dataclass(frozen=True)
class TreeData:
    """A perfect BST in BFS layout.

    keys/values: (n,) arrays, n = 2^{height+1} - 1, BFS order.
    n_real: number of non-sentinel entries.
    """

    keys: jax.Array
    values: jax.Array
    height: int
    n_real: int

    @property
    def n_nodes(self) -> int:
        return int(self.keys.shape[0])

    def level(self, l: int) -> Tuple[jax.Array, jax.Array]:
        """The ``l``-th "BRAM partition": (keys, values) of one tree level."""
        o, s = level_offset(l), level_size(l)
        return self.keys[o : o + s], self.values[o : o + s]

    def register_layer(self, levels: int) -> Tuple[jax.Array, jax.Array]:
        """Top ``levels`` levels flattened -- the FPGA register layer."""
        n = level_offset(levels)
        return self.keys[:n], self.values[:n]

    def subtree(self, split_level: int, index: int) -> "TreeData":
        """Vertical partition: the ``index``-th subtree rooted at ``split_level``.

        In BFS layout, subtree ``s`` owns, at global level ``l >= split_level``,
        the offsets ``p`` with ``p >> (l - split_level) == s``; locally that is
        level ``l' = l - split_level`` offset ``p' = p - s * 2^{l'}``.
        """
        sub_h = self.height - split_level
        idx = subtree_gather_indices(self.height, split_level, index)
        return TreeData(
            keys=self.keys[idx],
            values=self.values[idx],
            height=sub_h,
            n_real=int((np.asarray(self.keys[idx]) != SENTINEL_KEY).sum()),
        )


def subtree_gather_indices(height: int, split_level: int, index: int) -> np.ndarray:
    """Global BFS indices of subtree ``index`` rooted at ``split_level``."""
    out = []
    for l_local in range(height - split_level + 1):
        l = split_level + l_local
        p = index * (1 << l_local) + np.arange(1 << l_local)
        out.append(level_offset(l) + p)
    return np.concatenate(out)


def all_subtree_gather_indices(height: int, split_level: int) -> np.ndarray:
    """(n_subtrees, subtree_nodes) gather map for every vertical partition."""
    n_sub = 1 << split_level
    return np.stack(
        [subtree_gather_indices(height, split_level, s) for s in range(n_sub)]
    )


def eytzinger_from_sorted(
    sorted_keys: np.ndarray, sorted_values: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Lay out sorted key/value pairs as a perfect BFS tree (vectorized).

    For a perfect tree of height H, the node at level ``l`` offset ``p`` has
    in-order rank ``(2p + 1) * 2^{H-l} - 1``; inverting that map assigns each
    sorted element its BFS slot without recursion.
    """
    sorted_keys = np.asarray(sorted_keys)
    sorted_values = np.asarray(sorted_values)
    if sorted_keys.ndim != 1 or sorted_keys.shape != sorted_values.shape:
        raise ValueError("keys/values must be equal-length 1-D arrays")
    if sorted_keys.size == 0:
        raise ValueError("empty tree")
    if not np.all(sorted_keys[:-1] < sorted_keys[1:]):
        raise ValueError("keys must be strictly increasing")

    n_real = sorted_keys.size
    H = height_for(n_real)
    n = (1 << (H + 1)) - 1

    padded_keys = np.full(n, SENTINEL_KEY, dtype=np.int32)
    padded_vals = np.full(n, SENTINEL_VALUE, dtype=np.int32)
    padded_keys[:n_real] = sorted_keys.astype(np.int32)
    padded_vals[:n_real] = sorted_values.astype(np.int32)
    # Sentinel keys must stay the largest: they land in the right-most
    # in-order ranks automatically because SENTINEL_KEY > every real key.

    bfs_keys = np.empty(n, dtype=np.int32)
    bfs_vals = np.empty(n, dtype=np.int32)
    for l in range(H + 1):
        p = np.arange(1 << l)
        rank = (2 * p + 1) * (1 << (H - l)) - 1
        o = level_offset(l)
        bfs_keys[o : o + (1 << l)] = padded_keys[rank]
        bfs_vals[o : o + (1 << l)] = padded_vals[rank]
    return bfs_keys, bfs_vals, H, n_real


def build_tree(keys: np.ndarray, values: np.ndarray) -> TreeData:
    """Build a TreeData from (unsorted) unique keys + values."""
    keys = np.asarray(keys, dtype=np.int32)
    values = np.asarray(values, dtype=np.int32)
    order = np.argsort(keys, kind="stable")
    k, v, h, n_real = eytzinger_from_sorted(keys[order], values[order])
    return TreeData(keys=jnp.asarray(k), values=jnp.asarray(v), height=h, n_real=n_real)


def search_reference(tree: TreeData, queries: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Pure-jnp oracle: batched BST descent in BFS layout.

    Returns (values, found).  Not-found queries get SENTINEL_VALUE.
    """
    n = tree.n_nodes

    def step(carry, _):
        idx, val, found = carry
        node_key = tree.keys[idx]
        node_val = tree.values[idx]
        hit = (node_key == queries) & ~found
        val = jnp.where(hit, node_val, val)
        found = found | hit
        go_right = queries > node_key
        nxt = 2 * idx + 1 + go_right.astype(idx.dtype)
        idx = jnp.where(found, idx, jnp.minimum(nxt, n - 1))
        return (idx, val, found), None

    B = queries.shape[0]
    init = (
        jnp.zeros((B,), dtype=jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, dtype=jnp.int32),
        jnp.zeros((B,), dtype=bool),
    )
    (idx, val, found), _ = jax.lax.scan(step, init, None, length=tree.height + 1)
    del idx
    return val, found


def left_subtree_sizes(height: int) -> np.ndarray:
    """Per-level left-subtree size ``2^{H-l} - 1`` of a height-``H`` tree.

    The ordered descent's rank arithmetic: taking the right branch at level
    ``l`` skips the node plus its entire left subtree -- ``2^{H-l}`` keys,
    all real whenever the node itself is real (sentinels pad only the top
    in-order ranks, so a real node's left subtree never contains one).
    """
    levels = np.arange(height + 1)
    return ((1 << (height - levels)) - 1).astype(np.int32)


@functools.lru_cache(maxsize=None)
def rank_to_bfs_indices(height: int) -> np.ndarray:
    """BFS index of every in-order rank (the sorted view of the layout).

    Inverts ``rank = (2p + 1) * 2^{H-l} - 1``: with ``t = rank + 1``, the
    number of trailing zero bits of ``t`` is ``H - l`` and the remaining odd
    factor is ``2p + 1``.  range_scan gathers consecutive ranks through this
    map instead of re-sorting (DESIGN.md §6).  Memoized per height (callers
    treat the array as read-only): compaction runs in the serving steady
    state and must not rebuild O(n) host maps per swap.
    """
    n = (1 << (height + 1)) - 1
    t = np.arange(1, n + 1, dtype=np.int64)
    z = np.log2(t & -t).astype(np.int64)  # trailing zeros, exact for 2^k
    level = height - z
    offset = ((t >> z) - 1) >> 1
    return (((1 << level) - 1) + offset).astype(np.int32)


@functools.lru_cache(maxsize=None)
def bfs_inorder_ranks(height: int) -> np.ndarray:
    """In-order rank of every BFS index (inverse of ``rank_to_bfs_indices``).

    The node at level ``l`` offset ``p`` has rank ``(2p + 1) * 2^{H-l} - 1``.
    Gathering a sorted array through this map IS the Eytzinger layout step --
    the device-side re-layout that ``layout_from_sorted_device`` (and the
    delta-compaction path, DESIGN.md §7) runs under ``jit``.  Memoized per
    height like its inverse (read-only contract).
    """
    n = (1 << (height + 1)) - 1
    out = np.empty(n, dtype=np.int32)
    for l in range(height + 1):
        p = np.arange(1 << l)
        o = level_offset(l)
        out[o : o + (1 << l)] = (2 * p + 1) * (1 << (height - l)) - 1
    return out


def layout_from_sorted_device(
    sorted_keys: jax.Array, sorted_values: jax.Array, n_real: int
) -> TreeData:
    """Build a TreeData from a DEVICE-resident sorted view (one gather).

    ``sorted_keys/values`` hold ``n_real`` real pairs in ascending key order
    followed by sentinel padding (any length >= n_real).  The perfect-tree
    height is derived from ``n_real`` (a host int -- the one scalar the
    delta write path syncs per compaction, DESIGN.md §7); the BFS image is a
    single gather through ``bfs_inorder_ranks``, so the arrays never leave
    the device.
    """
    if n_real < 1:
        raise ValueError("empty tree")
    h = height_for(n_real)
    n = (1 << (h + 1)) - 1
    pad = n - int(sorted_keys.shape[0])
    if pad > 0:
        sorted_keys = jnp.concatenate(
            [sorted_keys, jnp.full((pad,), SENTINEL_KEY, jnp.int32)]
        )
        sorted_values = jnp.concatenate(
            [sorted_values, jnp.full((pad,), SENTINEL_VALUE, jnp.int32)]
        )
    ranks = jnp.asarray(bfs_inorder_ranks(h))
    return TreeData(
        keys=sorted_keys[:n][ranks],
        values=sorted_values[:n][ranks],
        height=h,
        n_real=n_real,
    )


def _ordered_step(keys, values, queries, active, idx_clamp):
    """One ordered compare-descend scan step over BFS-layout operands.

    The single implementation behind both tree-level jnp descents (full
    reference, register-layer route); the independent twin lives in
    ``kernels/ref.bst_ordered_ref`` (deliberately separate ground truth for
    the kernel property tests).  ``idx_clamp`` bounds the child index for
    full-tree descents; the register route leaves it None because the final
    index must step past the register block to name the subtree.
    """

    def step(carry, left):
        idx, r = carry
        nk = keys[idx]
        nv = values[idx]
        live = ~r.found if active is None else active & ~r.found
        hit = (nk == queries) & live
        go_right = live & ~hit & (queries > nk)
        go_left = live & ~hit & (queries < nk)
        r = OrderedResult(
            value=jnp.where(hit, nv, r.value),
            found=r.found | hit,
            pred_key=jnp.where(go_right, nk, r.pred_key),
            pred_value=jnp.where(go_right, nv, r.pred_value),
            succ_key=jnp.where(go_left, nk, r.succ_key),
            succ_value=jnp.where(go_left, nv, r.succ_value),
            rank=r.rank
            + jnp.where(go_right, left + 1, 0)
            + jnp.where(hit, left, 0),
        )
        nxt = 2 * idx + 1 + go_right.astype(idx.dtype)
        if idx_clamp is not None:
            nxt = jnp.minimum(nxt, idx_clamp)
        frozen = r.found if active is None else r.found | ~active
        idx = jnp.where(frozen, idx, nxt)
        return (idx, r), None

    return step


def search_reference_ordered(
    tree: TreeData, queries: jax.Array, active: jax.Array | None = None
) -> OrderedResult:
    """Pure-jnp oracle for the ordered descent (DESIGN.md §6).

    One root-to-leaf pass per query yields the exact-match payload PLUS the
    strict predecessor/successor ancestors and the query's rank boundary.
    Bit-identical to the forest kernel's ordered outputs (property-tested).
    Queries must be real keys, i.e. strictly inside
    (NO_PRED_KEY, SENTINEL_KEY).
    """
    B = queries.shape[0]
    if active is None:
        active = jnp.ones((B,), dtype=bool)
    left_sizes = jnp.asarray(left_subtree_sizes(tree.height))
    step = _ordered_step(tree.keys, tree.values, queries, active, tree.n_nodes - 1)
    init = (jnp.zeros((B,), jnp.int32), init_ordered(B))
    (_, res), _ = jax.lax.scan(step, init, left_sizes)
    return res


def init_ordered(B: int) -> OrderedResult:
    """The ordered descent's identity state (also the inactive-lane output)."""
    return OrderedResult(
        value=jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        found=jnp.zeros((B,), bool),
        pred_key=jnp.full((B,), NO_PRED_KEY, jnp.int32),
        pred_value=jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        succ_key=jnp.full((B,), NO_SUCC_KEY, jnp.int32),
        succ_value=jnp.full((B,), SENTINEL_VALUE, jnp.int32),
        rank=jnp.zeros((B,), jnp.int32),
    )


def register_layer_route(
    tree: TreeData, queries: jax.Array, register_levels: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Descend the register layer only; route survivors to subtrees.

    Returns (subtree_id, value, found):
      * found=True  -> key matched inside the register layer, value valid.
      * found=False -> subtree_id in [0, 2^register_levels) names the vertical
        partition in which the descent must continue (paper Fig. 3).
    """
    if register_levels < 1:
        raise ValueError("need at least one register level (the root)")

    def step(carry, _):
        idx, val, found = carry
        node_key = tree.keys[idx]
        node_val = tree.values[idx]
        hit = (node_key == queries) & ~found
        val = jnp.where(hit, node_val, val)
        found = found | hit
        go_right = queries > node_key
        nxt = 2 * idx + 1 + go_right.astype(idx.dtype)
        idx = jnp.where(found, idx, nxt)
        return (idx, val, found), None

    B = queries.shape[0]
    init = (
        jnp.zeros((B,), dtype=jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, dtype=jnp.int32),
        jnp.zeros((B,), dtype=bool),
    )
    (idx, val, found), _ = jax.lax.scan(step, init, None, length=register_levels)
    # After `register_levels` steps, a live key's idx is a BFS index at level
    # `register_levels`; its offset there *is* the subtree id.
    subtree_id = jnp.clip(idx - level_offset(register_levels), 0, None)
    subtree_id = jnp.where(found, -1, subtree_id).astype(jnp.int32)
    return subtree_id, val, found


def register_layer_route_ordered(
    tree: TreeData, queries: jax.Array, register_levels: int, full_height: int
) -> Tuple[jax.Array, OrderedResult]:
    """Ordered variant of ``register_layer_route`` (DESIGN.md §6).

    Returns (subtree_id, partial OrderedResult): the register layer's
    contribution to predecessor/successor tracking and rank arithmetic.
    Rank contributions use ``full_height`` left-subtree sizes -- the register
    layer is a prefix of the FULL tree, so a right turn at global level ``l``
    skips ``2^{full_height - l}`` keys regardless of where the subtree split
    sits.  The subtree descent's local rank then simply adds on.
    """
    if register_levels < 1:
        raise ValueError("need at least one register level (the root)")
    B = queries.shape[0]
    left_sizes = jnp.asarray(left_subtree_sizes(full_height)[:register_levels])
    step = _ordered_step(tree.keys, tree.values, queries, None, None)
    init = (jnp.zeros((B,), jnp.int32), init_ordered(B))
    (idx, res), _ = jax.lax.scan(step, init, left_sizes)
    subtree_id = jnp.clip(idx - level_offset(register_levels), 0, None)
    subtree_id = jnp.where(res.found, -1, subtree_id).astype(jnp.int32)
    return subtree_id, res


def subtree_search(
    sub_keys: jax.Array,
    sub_values: jax.Array,
    sub_height: int,
    queries: jax.Array,
    active: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Descend one vertical partition (local BFS layout).

    ``active`` masks padded/irrelevant slots so they cannot fake a hit.
    """
    n = sub_keys.shape[0]

    def step(carry, _):
        idx, val, found = carry
        node_key = sub_keys[idx]
        node_val = sub_values[idx]
        hit = (node_key == queries) & ~found & active
        val = jnp.where(hit, node_val, val)
        found = found | hit
        go_right = queries > node_key
        nxt = 2 * idx + 1 + go_right.astype(idx.dtype)
        idx = jnp.where(found, idx, jnp.minimum(nxt, n - 1))
        return (idx, val, found), None

    B = queries.shape[0]
    init = (
        jnp.zeros((B,), dtype=jnp.int32),
        jnp.full((B,), SENTINEL_VALUE, dtype=jnp.int32),
        jnp.zeros((B,), dtype=bool),
    )
    (_, val, found), _ = jax.lax.scan(step, init, None, length=sub_height + 1)
    return val, found & active
