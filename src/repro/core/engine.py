"""BSTEngine: the TPU-native query engine with the paper's three strategies.

Strategies (paper §II):
  * ``hrz``   -- horizontal partitioning.  One tree, level-major layout, the
                 whole query chunk descends one level per step: the SIMD
                 rendition of the FPGA's level pipeline.
  * ``dup``   -- duplicated horizontal partitioning.  ``n_trees`` replicas;
                 on one chip this splits the chunk across replicas (pure
                 bandwidth trade), across chips it becomes data parallelism.
  * ``hyb``   -- hybrid horizontal-vertical partitioning.  The top
                 ``register_levels`` levels are a broadcast "register layer";
                 survivors are routed to ``n_trees`` vertical subtrees through
                 direct- or queue-mapped buffers and descend locally.

All strategies return bit-identical results (property-tested); they differ in
memory layout, dispatch traffic and -- in the distributed engine -- collective
pattern.  Functional equivalence is exactly the paper's situation: every
implementation finds the same keys, only throughput differs.

The engine itself is a thin driver: each strategy compiles to a
``core.plans.SearchPlan`` whose phase implementations (route / dispatch /
descend / combine) are shared verbatim with ``core/distributed.py``, and
whose descent lowers to the single forest-batched Pallas kernel when
``use_kernel=True`` (DESIGN.md §2, §4).

The entry point is ``query(op, ...)`` -- one API for the whole ordered-query
workload family (DESIGN.md §6): ``lookup``, ``predecessor``, ``successor``,
``range_count`` and ``range_scan`` all lower through the same plan phases
and the same kernel; ``lookup()`` remains as the membership shorthand.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core.tree import TreeData


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Compile-time reconfigurable parameters (paper: "fully reconfigurable")."""

    strategy: str = "hrz"  # hrz | dup | hyb
    n_trees: int = 1  # replicas (dup) or vertical subtrees (hyb)
    mapping: str = "queue"  # direct | queue   (hyb only)
    register_levels: Optional[int] = None  # default: log2(n_trees) for hyb
    # Buffer capacity per subtree as a multiple of the fair share B/n_trees.
    buffer_slack: float = 2.0
    use_kernel: bool = False  # route descent through the Pallas forest kernel
    interpret: bool = True  # Pallas interpret mode (CPU container)

    def resolved_register_levels(self) -> int:
        return plans_lib.resolved_register_levels(self.n_trees, self.register_levels)

    @property
    def name(self) -> str:
        if self.strategy == "hrz":
            return "Hrz"
        if self.strategy == "dup":
            return f"Dup{self.n_trees}"
        suffix = "q" if self.mapping == "queue" else ""
        return f"Hyb{self.n_trees}{suffix}"


# Preset configurations matching the paper's evaluated implementations.
PAPER_CONFIGS = {
    "Hrz": EngineConfig(strategy="hrz"),
    "Dup4": EngineConfig(strategy="dup", n_trees=4),
    "Dup8": EngineConfig(strategy="dup", n_trees=8),
    "Hyb4": EngineConfig(strategy="hyb", n_trees=4, mapping="direct"),
    "Hyb4q": EngineConfig(strategy="hyb", n_trees=4, mapping="queue"),
    "Hyb8": EngineConfig(strategy="hyb", n_trees=8, mapping="direct"),
    "Hyb8q": EngineConfig(strategy="hyb", n_trees=8, mapping="queue"),
}


class BSTEngine:
    """Build once, look up batches of keys many times."""

    def __init__(self, keys, values, config: EngineConfig = EngineConfig()):
        self.config = config
        self.tree = tree_lib.build_tree(np.asarray(keys), np.asarray(values))
        self._finalize()

    @classmethod
    def from_tree(cls, tree: TreeData, config: EngineConfig = EngineConfig()):
        """Wrap an existing immutable snapshot (serving's bulk-update swap)."""
        self = cls.__new__(cls)
        self.config = config
        self.tree = tree
        self._finalize()
        return self

    # ------------------------------------------------------------------ build
    def _finalize(self) -> None:
        cfg = self.config
        self.plan = plans_lib.make_plan(
            self.tree,
            strategy=cfg.strategy,
            n_trees=cfg.n_trees,
            mapping=cfg.mapping,
            register_levels=cfg.register_levels,
            buffer_slack=cfg.buffer_slack,
        )
        self._query_cache: Dict[Tuple[str, int], callable] = {}

    # ------------------------------------------------------------------ query
    def query(self, op: str, queries, queries_hi=None, *, k: int = 8):
        """Run one query op over a 1-D int32 batch (DESIGN.md §6).

        * ``query("lookup", q)``            -> (values, found)
        * ``query("predecessor", q)``       -> (keys, values, ok): floor(q)
        * ``query("successor", q)``         -> (keys, values, ok): ceiling(q)
        * ``query("range_count", lo, hi)``  -> counts of keys in [lo, hi]
        * ``query("range_scan", lo, hi, k=8)`` -> (keys (B, k), values,
          counts): the first ``k`` in-order pairs per range.

        One jitted function per (op, k) -- every op runs the same plan
        phases and the single forest-batched descent.
        """
        plans_lib.validate_op(op, queries_hi is not None)
        # k shapes only range_scan's epilogue; other ops share one cache slot
        # so varying k cannot trigger redundant retraces.
        key = (op, k) if op == "range_scan" else (op, None)
        fn = self._query_cache.get(key)
        if fn is None:
            fn = jax.jit(
                functools.partial(
                    plans_lib.ordered_query,
                    self.plan,
                    op,
                    k=k,
                    use_kernel=self.config.use_kernel,
                    interpret=self.config.interpret,
                )
            )
            self._query_cache[key] = fn
        queries = jnp.asarray(queries, dtype=jnp.int32)
        if op in plans_lib.RANGE_OPS:
            return fn(queries, jnp.asarray(queries_hi, dtype=jnp.int32))
        return fn(queries)

    # ----------------------------------------------------------------- lookup
    def lookup(self, queries) -> Tuple[jax.Array, jax.Array]:
        """(values, found) for a 1-D int32 query batch."""
        return self.query("lookup", queries)

    # ------------------------------------------------------------- accounting
    def memory_nodes(self) -> int:
        """Stored nodes (the paper's Fig. 8 memory metric)."""
        return self.plan.memory_nodes()
