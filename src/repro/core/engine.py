"""BSTEngine: the TPU-native query engine with the paper's three strategies.

Strategies (paper §II):
  * ``hrz``   -- horizontal partitioning.  One tree, level-major layout, the
                 whole query chunk descends one level per step: the SIMD
                 rendition of the FPGA's level pipeline.
  * ``dup``   -- duplicated horizontal partitioning.  ``n_trees`` replicas;
                 on one chip this splits the chunk across replicas (pure
                 bandwidth trade), across chips it becomes data parallelism.
  * ``hyb``   -- hybrid horizontal-vertical partitioning.  The top
                 ``register_levels`` levels are a broadcast "register layer";
                 survivors are routed to ``n_trees`` vertical subtrees through
                 direct- or queue-mapped buffers and descend locally.

All strategies return bit-identical results (property-tested); they differ in
memory layout, dispatch traffic and -- in the distributed engine -- collective
pattern.  Functional equivalence is exactly the paper's situation: every
implementation finds the same keys, only throughput differs.

The engine itself is a thin driver: each strategy compiles to a
``core.plans.SearchPlan`` whose phase implementations (route / dispatch /
descend / combine) are shared verbatim with ``core/distributed.py``, and
whose descent lowers to the single forest-batched Pallas kernel when
``use_kernel=True`` (DESIGN.md §2, §4).

The entry point is ``query(op, ...)`` -- one API for the whole ordered-query
workload family (DESIGN.md §6): ``lookup``, ``predecessor``, ``successor``,
``range_count`` and ``range_scan`` all lower through the same plan phases
and the same kernel; ``lookup()`` remains as the membership shorthand.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import invariants
from repro.core import delta as delta_lib
from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core import updates as updates_lib
from repro.core.tree import TreeData


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Compile-time reconfigurable parameters (paper: "fully reconfigurable")."""

    strategy: str = "hrz"  # hrz | dup | hyb
    n_trees: int = 1  # replicas (dup) or vertical subtrees (hyb)
    mapping: str = "queue"  # direct | queue   (hyb only)
    register_levels: Optional[int] = None  # default: log2(n_trees) for hyb
    # Buffer capacity per subtree as a multiple of the fair share B/n_trees.
    buffer_slack: float = 2.0
    use_kernel: bool = False  # route descent through the Pallas forest kernel
    interpret: bool = True  # Pallas interpret mode (CPU container)
    # Live write path (DESIGN.md §7): > 0 attaches a delta buffer of that
    # many slots to every query, enabling device-side apply_updates with
    # bulk compaction at the high-water mark.  0 keeps the engine read-only
    # (updates then mean a full snapshot rebuild, the pre-§7 story).
    delta_capacity: int = 0
    delta_high_water: Optional[int] = None  # default: 3/4 of the capacity

    def __post_init__(self) -> None:
        # Shared with repro.analysis.contracts: the checker verifies the
        # same bounds statically, so neither side can drift (DESIGN.md §10).
        invariants.check_delta_config(self.delta_capacity, self.delta_high_water)

    def resolved_register_levels(self) -> int:
        return plans_lib.resolved_register_levels(self.n_trees, self.register_levels)

    def resolved_high_water(self) -> int:
        return invariants.resolved_high_water(
            self.delta_capacity, self.delta_high_water
        )

    @property
    def name(self) -> str:
        if self.strategy == "hrz":
            return "Hrz"
        if self.strategy == "dup":
            return f"Dup{self.n_trees}"
        suffix = "q" if self.mapping == "queue" else ""
        return f"Hyb{self.n_trees}{suffix}"


# Preset configurations matching the paper's evaluated implementations.
PAPER_CONFIGS = {
    "Hrz": EngineConfig(strategy="hrz"),
    "Dup4": EngineConfig(strategy="dup", n_trees=4),
    "Dup8": EngineConfig(strategy="dup", n_trees=8),
    "Hyb4": EngineConfig(strategy="hyb", n_trees=4, mapping="direct"),
    "Hyb4q": EngineConfig(strategy="hyb", n_trees=4, mapping="queue"),
    "Hyb8": EngineConfig(strategy="hyb", n_trees=8, mapping="direct"),
    "Hyb8q": EngineConfig(strategy="hyb", n_trees=8, mapping="queue"),
}


class BSTEngine:
    """Build once, look up batches of keys many times."""

    def __init__(self, keys, values, config: EngineConfig = EngineConfig()):
        self.config = config
        self.tree = tree_lib.build_tree(np.asarray(keys), np.asarray(values))
        self._finalize()

    @classmethod
    def from_tree(cls, tree: TreeData, config: EngineConfig = EngineConfig()):
        """Wrap an existing immutable snapshot (serving's bulk-update swap)."""
        self = cls.__new__(cls)
        self.config = config
        self.tree = tree
        self._finalize()
        return self

    # ------------------------------------------------------------------ build
    def _finalize(self) -> None:
        cfg = self.config
        self.plan = plans_lib.make_plan(
            self.tree,
            strategy=cfg.strategy,
            n_trees=cfg.n_trees,
            mapping=cfg.mapping,
            register_levels=cfg.register_levels,
            buffer_slack=cfg.buffer_slack,
        )
        self._query_cache: Dict[Tuple[str, int], callable] = {}
        # Live write path (DESIGN.md §7): a fresh empty buffer per snapshot.
        self.delta = (
            delta_lib.empty(cfg.delta_capacity) if cfg.delta_capacity > 0 else None
        )
        self._ingest = jax.jit(self._ingest_step) if self.delta is not None else None
        # Host-side occupancy upper bound (<= sum of batch sizes since the
        # last compaction): the compaction trigger never syncs the device
        # count scalar, at the cost of compacting a touch early.
        self._pending_writes = 0
        self.compactions = getattr(self, "compactions", 0)
        # Snapshot-swap hook (DESIGN.md §9): a swap can fire deep inside
        # apply_ops' chunk loop (compaction) or in apply_updates' bulk
        # rebuild, and anything compiled against the OLD snapshot (the
        # sharded server's shard_map programs) must rebuild before the next
        # read.  Called with the fresh TreeData after EVERY snapshot swap;
        # None by default.
        self.on_snapshot = getattr(self, "on_snapshot", None)

    # ------------------------------------------------------------------ query
    def query(self, op: str, queries, queries_hi=None, *, k: int = 8):
        """Run one query op over a 1-D int32 batch (DESIGN.md §6).

        * ``query("lookup", q)``            -> (values, found)
        * ``query("predecessor", q)``       -> (keys, values, ok): floor(q)
        * ``query("successor", q)``         -> (keys, values, ok): ceiling(q)
        * ``query("range_count", lo, hi)``  -> counts of keys in [lo, hi]
        * ``query("range_scan", lo, hi, k=8)`` -> (keys (B, k), values,
          counts): the first ``k`` in-order pairs per range.

        One jitted function per (op, k) -- every op runs the same plan
        phases and the single forest-batched descent.
        """
        plans_lib.validate_op(op, queries_hi is not None)
        # k shapes only range_scan's epilogue; other ops share one cache slot
        # so varying k cannot trigger redundant retraces.
        key = (op, k) if op == "range_scan" else (op, None)
        fn = self._query_cache.get(key)
        if fn is None:
            fn = jax.jit(
                functools.partial(
                    plans_lib.ordered_query,
                    self.plan,
                    op,
                    k=k,
                    use_kernel=self.config.use_kernel,
                    interpret=self.config.interpret,
                )
            )
            self._query_cache[key] = fn
        queries = jnp.asarray(queries, dtype=jnp.int32)
        # The delta buffer is a traced argument (its arrays change per write
        # batch but never in shape), so writes do not retrace queries.
        kw = {} if self.delta is None else {"delta": self.delta}
        if op in plans_lib.RANGE_OPS:
            return fn(queries, jnp.asarray(queries_hi, dtype=jnp.int32), **kw)
        return fn(queries, **kw)

    # ----------------------------------------------------------------- lookup
    def lookup(self, queries) -> Tuple[jax.Array, jax.Array]:
        """(values, found) for a 1-D int32 query batch."""
        return self.query("lookup", queries)

    # ------------------------------------------------------------------ write
    def _ingest_step(self, delta, keys, values, deletes, valid):
        """One write-batch ingest (jitted in ``_finalize``; jax caches one
        trace per batch shape automatically).

        The batch descends the engine's OWN datapath (same plan, same
        kernel/reference choice as queries) to classify each key against
        the snapshot, then merges into the sorted buffer -- pure jnp end
        to end, so updates never leave the device (DESIGN.md §7).
        """
        res = plans_lib.execute_plan_ordered(
            self.plan,
            keys,
            use_kernel=self.config.use_kernel,
            interpret=self.config.interpret,
        )
        return delta_lib.ingest(
            delta, keys, values, deletes, valid, res.found, res.rank
        )

    def apply_ops(self, keys, values, deletes, valid=None) -> None:
        """Apply a mixed batch of upserts/tombstones in submission order.

        ``keys``/``values`` are int32 arrays, ``deletes`` a bool mask
        (True = tombstone; the value lane is ignored), ``valid`` an
        optional bool mask for padding lanes (fixed jit shapes upstream).
        Requires ``delta_capacity > 0``.  The buffer absorbs the batch on
        device; a batch larger than the capacity is chunked through
        interleaved compactions (every chunk's valid-lane count fits the
        buffer by construction, and a compaction runs before any chunk
        that would push occupancy past the capacity), so a single
        oversized batch can never overflow the buffer between triggers.
        The high-water mark additionally compacts after the batch -- never
        mid-chunk, so readers always see a consistent snapshot + buffer
        pair.
        """
        if self.delta is None:
            raise ValueError(
                "write path disabled (delta_capacity == 0): construct the "
                "engine with EngineConfig(delta_capacity > 0), or use "
                "core.updates bulk maintenance + snapshot swap"
            )
        keys = np.atleast_1d(np.asarray(keys, np.int32))
        values = np.atleast_1d(np.asarray(values, np.int32))
        deletes = np.atleast_1d(np.asarray(deletes, bool))
        if not (keys.shape == values.shape == deletes.shape) or keys.ndim != 1:
            raise ValueError("keys/values/deletes must be equal-length 1-D")
        valid = (
            np.ones(keys.shape, bool)
            if valid is None
            else np.atleast_1d(np.asarray(valid, bool))
        )
        if valid.shape != keys.shape:
            raise ValueError("valid mask must match the batch shape")
        cap = self.config.delta_capacity
        high = self.config.resolved_high_water()
        for lo in range(0, keys.size, cap):
            sl = slice(lo, lo + cap)
            m = int(valid[sl].sum())  # <= cap: the slice is cap lanes long
            if m == 0:
                continue
            if self._pending_writes + m > cap:
                self.compact()
            self.delta = self._ingest(
                self.delta,
                jnp.asarray(keys[sl]),
                jnp.asarray(values[sl]),
                jnp.asarray(deletes[sl]),
                jnp.asarray(valid[sl]),
            )
            # _pending_writes upper-bounds buffer occupancy (ingest dedups,
            # so the true count can only be lower); the invariant the chunk
            # loop maintains is _pending_writes <= cap at every step.
            self._pending_writes += m
            assert self._pending_writes <= cap
        if self._pending_writes >= high:
            self.compact()

    def apply_updates(
        self, insert_keys=None, insert_values=None, delete_keys=None
    ) -> TreeData:
        """Insert/delete convenience over ``apply_ops`` (deletes first, so
        an upsert of a just-deleted key lands -- the historical contract).

        With the write path enabled the batch lands in the delta buffer
        and the snapshot only changes at compaction; without it, falls
        back to ``core.updates`` bulk maintenance (full rebuild).  Returns
        the current snapshot either way.
        """
        dk = np.atleast_1d(np.asarray(delete_keys, np.int32)) if (
            delete_keys is not None and len(np.atleast_1d(delete_keys))
        ) else np.empty(0, np.int32)
        ik = np.atleast_1d(np.asarray(insert_keys, np.int32)) if (
            insert_keys is not None and len(np.atleast_1d(insert_keys))
        ) else np.empty(0, np.int32)
        if ik.size and insert_values is None:
            raise ValueError("insert_keys needs insert_values")
        iv = (
            np.atleast_1d(np.asarray(insert_values, np.int32))
            if ik.size
            else np.empty(0, np.int32)
        )
        if self.delta is None:
            tree = self.tree
            if dk.size:
                tree = updates_lib.bulk_delete(tree, dk)
            if ik.size:
                tree = updates_lib.bulk_insert(tree, ik, iv)
            self.tree = tree
            self._finalize()
            if self.on_snapshot is not None:
                self.on_snapshot(self.tree)
            return tree
        keys = np.concatenate([dk, ik])
        values = np.concatenate([np.zeros(dk.size, np.int32), iv])
        deletes = np.concatenate([np.ones(dk.size, bool), np.zeros(ik.size, bool)])
        if keys.size:
            self.apply_ops(keys, values, deletes)
        return self.tree

    def compact(self) -> TreeData:
        """Absorb the delta buffer into a fresh perfect snapshot.

        Device-side merge + Eytzinger re-layout (one host sync for the new
        key count, which fixes the static height); the plan and jit caches
        rebuild against the new snapshot, and the buffer comes back empty.
        No-op while nothing is buffered.
        """
        if self.delta is None or self._pending_writes == 0:
            return self.tree
        self.tree = delta_lib.compact(self.tree, self.delta)
        self.compactions += 1
        self._finalize()
        if self.on_snapshot is not None:
            self.on_snapshot(self.tree)
        return self.tree

    def pending_writes(self) -> int:
        """Upper bound on buffered entries (0 right after a compaction)."""
        return self._pending_writes

    # ------------------------------------------------------------- accounting
    def memory_nodes(self) -> int:
        """Stored nodes (the paper's Fig. 8 memory metric)."""
        return self.plan.memory_nodes()
