"""BSTEngine: the TPU-native lookup engine with the paper's four strategies.

Strategies (paper §II):
  * ``hrz``   -- horizontal partitioning.  One tree, level-major layout, the
                 whole query chunk descends one level per step: the SIMD
                 rendition of the FPGA's level pipeline.
  * ``dup``   -- duplicated horizontal partitioning.  ``n_trees`` replicas;
                 on one chip this splits the chunk across replicas (pure
                 bandwidth trade), across chips it becomes data parallelism.
  * ``hyb``   -- hybrid horizontal-vertical partitioning.  The top
                 ``register_levels`` levels are a broadcast "register layer";
                 survivors are routed to ``n_trees`` vertical subtrees through
                 direct- or queue-mapped buffers and descend locally.

All strategies return bit-identical results (property-tested); they differ in
memory layout, dispatch traffic and -- in the distributed engine -- collective
pattern.  Functional equivalence is exactly the paper's situation: every
implementation finds the same keys, only throughput differs.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buffers as buf
from repro.core import tree as tree_lib
from repro.core.tree import TreeData


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Compile-time reconfigurable parameters (paper: "fully reconfigurable")."""

    strategy: str = "hrz"  # hrz | dup | hyb
    n_trees: int = 1  # replicas (dup) or vertical subtrees (hyb)
    mapping: str = "queue"  # direct | queue   (hyb only)
    register_levels: Optional[int] = None  # default: log2(n_trees) for hyb
    # Buffer capacity per subtree as a multiple of the fair share B/n_trees.
    buffer_slack: float = 2.0
    use_kernel: bool = False  # route descent through the Pallas kernel
    interpret: bool = True  # Pallas interpret mode (CPU container)

    def resolved_register_levels(self) -> int:
        if self.register_levels is not None:
            return self.register_levels
        return max(1, int(math.log2(max(self.n_trees, 2))))

    @property
    def name(self) -> str:
        if self.strategy == "hrz":
            return "Hrz"
        if self.strategy == "dup":
            return f"Dup{self.n_trees}"
        suffix = "q" if self.mapping == "queue" else ""
        return f"Hyb{self.n_trees}{suffix}"


# Preset configurations matching the paper's evaluated implementations.
PAPER_CONFIGS = {
    "Hrz": EngineConfig(strategy="hrz"),
    "Dup4": EngineConfig(strategy="dup", n_trees=4),
    "Dup8": EngineConfig(strategy="dup", n_trees=8),
    "Hyb4": EngineConfig(strategy="hyb", n_trees=4, mapping="direct"),
    "Hyb4q": EngineConfig(strategy="hyb", n_trees=4, mapping="queue"),
    "Hyb8": EngineConfig(strategy="hyb", n_trees=8, mapping="direct"),
    "Hyb8q": EngineConfig(strategy="hyb", n_trees=8, mapping="queue"),
}


class BSTEngine:
    """Build once, look up batches of keys many times."""

    def __init__(self, keys, values, config: EngineConfig = EngineConfig()):
        self.config = config
        self.tree = tree_lib.build_tree(np.asarray(keys), np.asarray(values))
        self._prepare()
        self._lookup = jax.jit(self._lookup_impl)

    # ------------------------------------------------------------------ build
    def _prepare(self) -> None:
        cfg, t = self.config, self.tree
        if cfg.strategy == "hyb":
            r = cfg.resolved_register_levels()
            if (1 << r) < cfg.n_trees:
                raise ValueError(
                    f"register_levels={r} exposes {1 << r} subtrees < n_trees={cfg.n_trees}"
                )
            if r > t.height:
                raise ValueError("register layer deeper than the tree")
            self.split_level = int(math.log2(cfg.n_trees))
            if self.split_level != math.log2(cfg.n_trees):
                raise ValueError("n_trees must be a power of two")
            # Register layer = levels [0, split_level); subtrees hang below.
            idx = tree_lib.all_subtree_gather_indices(t.height, self.split_level)
            self.sub_keys = t.keys[jnp.asarray(idx)]  # (n_trees, sub_n)
            self.sub_values = t.values[jnp.asarray(idx)]
            self.sub_height = t.height - self.split_level
        elif cfg.strategy == "dup":
            if cfg.n_trees < 1:
                raise ValueError("dup needs n_trees >= 1")
        elif cfg.strategy != "hrz":
            raise ValueError(f"unknown strategy {cfg.strategy!r}")

    # ----------------------------------------------------------------- lookup
    def lookup(self, queries) -> Tuple[jax.Array, jax.Array]:
        """(values, found) for a 1-D int32 query batch."""
        queries = jnp.asarray(queries, dtype=jnp.int32)
        return self._lookup(queries)

    def _lookup_impl(self, queries: jax.Array):
        cfg = self.config
        if cfg.strategy == "hrz":
            return self._search_whole(queries)
        if cfg.strategy == "dup":
            # n_trees replicas each take a contiguous slice of the chunk.
            B = queries.shape[0]
            n = cfg.n_trees
            pad = (-B) % n
            q = jnp.pad(queries, (0, pad)).reshape(n, -1)
            vals, found = jax.vmap(self._search_whole)(q)
            return vals.reshape(-1)[:B], found.reshape(-1)[:B]
        return self._lookup_hybrid(queries)

    def _search_whole(self, queries: jax.Array):
        if self.config.use_kernel:
            from repro.kernels import ops as kops

            return kops.bst_search(
                self.tree.keys,
                self.tree.values,
                queries,
                height=self.tree.height,
                interpret=self.config.interpret,
            )
        return tree_lib.search_reference(self.tree, queries)

    def _lookup_hybrid(self, queries: jax.Array):
        cfg, t = self.config, self.tree
        B = queries.shape[0]
        n = cfg.n_trees
        # Phase 1: register layer (broadcast storage, no port limit).
        dest, reg_val, reg_found = tree_lib.register_layer_route(
            t, queries, self.split_level
        )
        active = ~reg_found
        # Phase 2: buffer dispatch (the paper's direct/queue mapping).
        capacity = int(math.ceil(B / n * cfg.buffer_slack))
        plan = buf.dispatch(cfg.mapping, dest, n, capacity, active=active)
        per_sub_q = buf.gather_from_buffers(queries, plan.buffers, fill_value=0)
        per_sub_active = plan.buffers >= 0
        # Phase 3: per-subtree descent (vmapped over vertical partitions).
        if cfg.use_kernel:
            from repro.kernels import ops as kops

            sub_vals, sub_found = jax.vmap(
                lambda k, v, q, a: kops.bst_search(
                    k,
                    v,
                    q,
                    height=self.sub_height,
                    active=a,
                    interpret=cfg.interpret,
                )
            )(self.sub_keys, self.sub_values, per_sub_q, per_sub_active)
        else:
            sub_vals, sub_found = jax.vmap(
                lambda k, v, q, a: tree_lib.subtree_search(
                    k, v, self.sub_height, q, a
                )
            )(self.sub_keys, self.sub_values, per_sub_q, per_sub_active)
        # Phase 4: combine.  Overflowed items (plan.overflow) retry through a
        # stall round -- the software analogue of the frontend stall.
        got_val = buf.combine_to_chunk(
            sub_vals, plan.buffers, B, fill_value=tree_lib.SENTINEL_VALUE
        )
        got_found = buf.combine_to_chunk(sub_found, plan.buffers, B, fill_value=False)
        val = jnp.where(reg_found, reg_val, got_val)
        found = reg_found | got_found

        def retry(args):
            val, found = args
            # Stall round: the overflowed minority re-descends the whole tree.
            r_val, r_found = tree_lib.search_reference(t, queries)
            val = jnp.where(plan.overflow, r_val, val)
            found = jnp.where(plan.overflow, r_found, found)
            return val, found

        val, found = jax.lax.cond(
            jnp.any(plan.overflow), retry, lambda a: a, (val, found)
        )
        return val, found

    # ------------------------------------------------------------- accounting
    def memory_nodes(self) -> int:
        """Stored nodes (the paper's Fig. 8 memory metric)."""
        if self.config.strategy == "dup":
            return self.tree.n_nodes * self.config.n_trees
        return self.tree.n_nodes
