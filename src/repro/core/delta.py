"""Device-side delta buffer: the live write path (DESIGN.md §7).

The paper defers Insert/Delete, and until now the repo's only rendition was
the host-side O(n + m) full rebuild between snapshot swaps -- fine for
read-mostly streams, fatal for continuous writes.  This module is the
LSM-style fix, shaped after the level-wise batch-maintenance idiom (see
PAPERS.md): pending upserts and tombstones accumulate in a small sorted
**delta buffer** that is searched in the same pass as the main tree, and a
bulk **compaction** merges the buffer into a fresh perfect snapshot when it
crosses a high-water mark.  The deeply pipelined search datapath of the
source paper stays untouched -- the buffer simply rides the forest
``pallas_call`` as one extra (tiny) operand, like the register layer does,
for EVERY single-chip strategy (hyb resolves it inside the same kernel
pass as its dispatch/replay pipeline, DESIGN.md §8).

Entry resolution per query: ``delta-hit > tombstone > tree-hit``.  Each
entry records, at ingest time, whether its key exists in the backing
snapshot (``in_tree``) and the key's tree rank -- both fall out of one
ordered descent over the immutable snapshot, so writes ride the same
datapath reads do.  From those two bits every entry gets a signed **rank
weight**

    w = +1  upsert of a new key        (grows the key set)
    w =  0  upsert of an existing key  (value override only)
    w = -1  tombstone of a stored key  (shrinks the key set)
    w =  0  tombstone of an absent key (no-op, kept only to shadow
                                        earlier buffered upserts)

and the merged rank of any query is ``tree_rank(q) + sum of weights of
entries with key < q`` -- exact, associative, and computable per lane with
one broadcast compare against the sorted buffer.  Ordered epilogues
(predecessor / successor / range_scan) then *select by merged rank*
(``select_merged``): the element at merged rank ``j`` is either a live
delta entry whose own merged rank is ``j``, or a tree key inside one of the
C + 1 gaps between consecutive delta keys, at tree rank ``j`` minus that
gap's weight prefix.  Tombstoned tree keys coincide with buffer keys, i.e.
gap *boundaries*, so the strict-gap test excludes them for free.

Everything here is pure jnp with static shapes (buffer capacity and batch
sizes are compile-time constants), so ingest, search and compaction all run
under ``jit`` -- updates never leave the device.  The single host sync in
the whole write path is the new key count read at compaction time, needed
to pick the next snapshot's (static) perfect-tree height.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import runtime as analysis_runtime
from repro.core import tree as tree_lib
from repro.core.tree import OrderedResult, TreeData
from repro.kernels import ops as kops


class DeltaBuffer(NamedTuple):
    """Fixed-capacity sorted buffer of pending upserts and tombstones.

    keys:      (C,) int32, ascending; SENTINEL_KEY marks empty slots (they
               self-sort to the tail, exactly like tree padding).
    values:    (C,) int32 upsert payloads (ignored for tombstones).
    tombstone: (C,) bool -- entry deletes its key instead of upserting it.
    in_tree:   (C,) bool -- key exists in the backing snapshot (frozen at
               ingest; the snapshot is immutable until compaction).
    tree_rank: (C,) int32 -- snapshot rank of the key at ingest time.
    count:     () int32 -- live entries (device scalar; the engine tracks a
               host-side upper bound so the hot path never syncs it).
    """

    keys: jax.Array
    values: jax.Array
    tombstone: jax.Array
    in_tree: jax.Array
    tree_rank: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return int(self.keys.shape[0])


def empty(capacity: int) -> DeltaBuffer:
    """A fresh all-sentinel buffer of ``capacity`` slots."""
    if capacity < 1:
        raise ValueError("delta capacity must be >= 1")
    return DeltaBuffer(
        keys=jnp.full((capacity,), tree_lib.SENTINEL_KEY, jnp.int32),
        values=jnp.full((capacity,), tree_lib.SENTINEL_VALUE, jnp.int32),
        tombstone=jnp.zeros((capacity,), bool),
        in_tree=jnp.zeros((capacity,), bool),
        tree_rank=jnp.zeros((capacity,), jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def weights(delta: DeltaBuffer) -> jax.Array:
    """Per-entry signed rank weight (see module doc); 0 for empty slots."""
    live = delta.keys != tree_lib.SENTINEL_KEY
    w = jnp.where(
        delta.in_tree,
        jnp.where(delta.tombstone, -1, 0),
        jnp.where(delta.tombstone, 0, 1),
    )
    return jnp.where(live, w, 0).astype(jnp.int32)


def net_keys(delta: DeltaBuffer) -> jax.Array:
    """Net change to the stored-key count once the buffer lands (() int32)."""
    return jnp.sum(weights(delta))


def operands(delta: DeltaBuffer) -> Tuple[jax.Array, ...]:
    """The four flat int32 arrays the kernel rides as extra operands:
    (keys, values, tombstone, weight)."""
    return (
        delta.keys,
        delta.values,
        delta.tombstone.astype(jnp.int32),
        weights(delta),
    )


# ------------------------------------------------------------------- ingest
def ingest(
    delta: DeltaBuffer,
    new_keys: jax.Array,
    new_values: jax.Array,
    new_deletes: jax.Array,
    new_valid: jax.Array,
    new_in_tree: jax.Array,
    new_tree_rank: jax.Array,
) -> DeltaBuffer:
    """Merge a batch of write ops (submission order, last-wins) into the
    buffer.  Pure jnp, static shapes, jit-safe.

    The batch arrives in submission order; a stable sort of
    ``old-entries || batch`` keyed on the key puts, for every duplicated
    key, the buffer's old entry first and batch occurrences in submission
    order -- so keeping the LAST occurrence per key is exactly the
    last-write-wins contract.  ``new_valid`` masks padding lanes (the
    server pads write chunks to a fixed jit shape).  The caller guarantees
    the merged live count fits the capacity (the engine compacts first
    otherwise); entries are never silently dropped.
    """
    C = delta.keys.shape[0]
    m = new_keys.shape[0]
    nk = jnp.where(new_valid, new_keys, tree_lib.SENTINEL_KEY).astype(jnp.int32)
    keys_cat = jnp.concatenate([delta.keys, nk])
    vals_cat = jnp.concatenate([delta.values, new_values.astype(jnp.int32)])
    tomb_cat = jnp.concatenate([delta.tombstone, new_deletes.astype(bool)])
    intree_cat = jnp.concatenate([delta.in_tree, new_in_tree.astype(bool)])
    rank_cat = jnp.concatenate([delta.tree_rank, new_tree_rank.astype(jnp.int32)])

    order = jnp.argsort(keys_cat, stable=True)
    k = keys_cat[order]
    # last occurrence per key wins; sentinels (padding / empty slots) drop
    keep = (k != tree_lib.SENTINEL_KEY) & jnp.concatenate(
        [k[:-1] != k[1:], jnp.ones((1,), bool)]
    )
    pos = jnp.cumsum(keep) - keep  # target slot among kept entries
    sink = C + m
    pos = jnp.where(keep, pos, sink).astype(jnp.int32)

    def place(src, fill, dtype):
        out = jnp.full((sink + 1,), fill, dtype)
        return out.at[pos].set(src[order].astype(dtype), mode="drop")[:C]

    return DeltaBuffer(
        keys=place(keys_cat, tree_lib.SENTINEL_KEY, jnp.int32),
        values=place(vals_cat, tree_lib.SENTINEL_VALUE, jnp.int32),
        tombstone=place(tomb_cat, False, bool),
        in_tree=place(intree_cat, False, bool),
        tree_rank=place(rank_cat, 0, jnp.int32),
        count=jnp.minimum(jnp.sum(keep), C).astype(jnp.int32),
    )


# ------------------------------------------------------------------ resolve
def resolve_operands(
    delta_ops: Tuple[jax.Array, ...],
    queries: jax.Array,
    active: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """``resolve`` over the four flat kernel operands (see ``operands``).

    This is the shard_map-friendly spelling: inside a sharded program the
    replicated buffer exists only as plain arrays (DESIGN.md §9 folds it
    on-device, per chip, against the local query slice), so the resolution
    cannot take the NamedTuple.  Same math as the in-``pallas_call``
    resolution, property-tested bit-identical.
    """
    return kops.bst_delta_resolve(*delta_ops, queries, active)


def resolve(
    delta: DeltaBuffer, queries: jax.Array, active: jax.Array | None = None
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-query buffer search: (hit, dead, value, weight_below).

    The jnp rendition of what the forest kernel computes in-``pallas_call``
    when the buffer rides as an operand (same math, property-tested
    bit-identical).  Every single-chip strategy -- hyb included since
    DESIGN.md §8 -- resolves in-kernel; the sharded drivers resolve the
    replicated operands inside their shard_map programs
    (``resolve_operands``), so no driver-level twin remains anywhere.
    """
    return resolve_operands(operands(delta), queries, active)


def merge_lookup(
    value: jax.Array,
    found: jax.Array,
    hit: jax.Array,
    dead: jax.Array,
    delta_value: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """delta-hit > tombstone > tree-hit, membership configuration."""
    return (
        jnp.where(hit, jnp.where(dead, tree_lib.SENTINEL_VALUE, delta_value), value),
        jnp.where(hit, ~dead, found),
    )


def merge_ordered(
    res: OrderedResult,
    hit: jax.Array,
    dead: jax.Array,
    delta_value: jax.Array,
    weight_below: jax.Array,
) -> OrderedResult:
    """Fold a buffer resolution into a tree ``OrderedResult``.

    value/found resolve ``delta-hit > tombstone > tree-hit``; the rank
    gains the signed weight of buffer entries below the query (the merged
    rank is then exact).  pred/succ fields stay tree-local -- the exact
    merged floor/ceiling comes from rank selection (``point_epilogue``),
    because a tombstone can kill the tree's tracked ancestor.
    """
    value, found = merge_lookup(res.value, res.found, hit, dead, delta_value)
    return res._replace(value=value, found=found, rank=res.rank + weight_below)


# ---------------------------------------------------------------- selection
def select_merged(
    sorted_keys: jax.Array,
    sorted_values: jax.Array,
    n_real: int,
    delta: DeltaBuffer,
    j: jax.Array,
    valid: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The live key/value at merged in-order rank ``j`` (exact).

    Two disjoint cases (see module doc): the element is a live buffer
    upsert whose merged rank ``tree_rank + exclusive-weight-prefix`` equals
    ``j``, or a tree key strictly inside one of the C + 1 gaps between
    consecutive buffer keys, at tree rank ``j - W_gap``.  Tombstoned and
    overwritten tree keys sit ON gap boundaries, so the strict inequality
    excludes them; overwrites are found through their buffer entry instead.
    ``j``/``valid`` broadcast over any batch shape; returns (keys, values,
    ok) where ``ok`` is False only for masked or out-of-range lanes.
    """
    w = weights(delta)
    live = delta.keys != tree_lib.SENTINEL_KEY
    present = live & ~delta.tombstone
    w_inc = jnp.cumsum(w)
    entry_rank = delta.tree_rank + (w_inc - w)  # exclusive prefix

    jj = j[..., None]
    vv = valid[..., None]
    hit_e = present & (entry_rank == jj) & vv
    from_delta = jnp.any(hit_e, axis=-1)
    d_key = jnp.sum(jnp.where(hit_e, delta.keys, 0), axis=-1)
    d_val = jnp.sum(jnp.where(hit_e, delta.values, 0), axis=-1)

    zero = jnp.zeros((1,), jnp.int32)
    w_gap = jnp.concatenate([zero, w_inc])  # (C+1,) weight prefix per gap
    lo_b = jnp.concatenate([jnp.full((1,), tree_lib.NO_PRED_KEY), delta.keys])
    hi_b = jnp.concatenate([delta.keys, jnp.full((1,), tree_lib.SENTINEL_KEY)])
    s = jj - w_gap  # candidate tree rank per gap
    s_ok = (s >= 0) & (s < n_real) & vv
    safe = jnp.clip(s, 0, sorted_keys.shape[0] - 1)
    t_key = sorted_keys[safe]
    in_gap = s_ok & (t_key > lo_b) & (t_key < hi_b)
    from_tree = jnp.any(in_gap, axis=-1)
    t_k = jnp.sum(jnp.where(in_gap, t_key, 0), axis=-1)
    t_v = jnp.sum(jnp.where(in_gap, sorted_values[safe], 0), axis=-1)

    ok = from_delta | from_tree
    key = jnp.where(from_delta, d_key, t_k)
    val = jnp.where(from_delta, d_val, t_v)
    return key, val, ok


def point_epilogue(
    op: str,
    queries: jax.Array,
    res: OrderedResult,
    sorted_keys: jax.Array,
    sorted_values: jax.Array,
    n_real: int,
    delta: DeltaBuffer,
):
    """Delta-aware twin of ``plans.point_epilogue`` (same op contract).

    ``res`` carries MERGED found/value/rank (``merge_ordered`` ran, in the
    kernel or the driver); floor/ceiling resolve by rank selection, which
    is exact even when tombstones kill the tree's tracked ancestors.  With
    an empty buffer every branch degenerates to the classic answers.
    """
    if op == "lookup":
        return res.value, res.found
    if op == "predecessor":
        need = ~res.found & (res.rank > 0)
        k, v, sel_ok = select_merged(
            sorted_keys, sorted_values, n_real, delta, res.rank - 1, need
        )
        got = need & sel_ok
        keys = jnp.where(res.found, queries, jnp.where(got, k, tree_lib.NO_PRED_KEY))
        values = jnp.where(
            res.found, res.value, jnp.where(got, v, tree_lib.SENTINEL_VALUE)
        )
        return keys, values, res.found | got
    # successor: ceiling(q) = the element at the query's own merged rank.
    total = n_real + net_keys(delta)
    need = ~res.found & (res.rank < total)
    k, v, sel_ok = select_merged(
        sorted_keys, sorted_values, n_real, delta, res.rank, need
    )
    got = need & sel_ok
    keys = jnp.where(res.found, queries, jnp.where(got, k, tree_lib.NO_SUCC_KEY))
    values = jnp.where(
        res.found, res.value, jnp.where(got, v, tree_lib.SENTINEL_VALUE)
    )
    return keys, values, res.found | got


def range_epilogue(
    op: str,
    sorted_keys: jax.Array,
    sorted_values: jax.Array,
    n_real: int,
    delta: DeltaBuffer,
    r_lo: OrderedResult,
    r_hi: OrderedResult,
    *,
    k: int = 8,
):
    """Delta-aware twin of ``plans.range_epilogue``.

    The count formula is unchanged -- ``rank_le(hi) - rank_lt(lo)`` over
    MERGED ranks -- and range_scan gathers consecutive merged ranks through
    ``select_merged`` instead of the static rank -> BFS map (the sorted
    view of tree + buffer exists only logically until compaction).
    """
    counts = jnp.maximum(r_hi.rank + r_hi.found.astype(jnp.int32) - r_lo.rank, 0)
    if op == "range_count":
        return counts
    take = jnp.minimum(counts, k)
    ranks = r_lo.rank[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < take[:, None]
    keys, values, _ = select_merged(
        sorted_keys, sorted_values, n_real, delta, ranks, valid
    )
    keys = jnp.where(valid, keys, tree_lib.SENTINEL_KEY)
    values = jnp.where(valid, values, tree_lib.SENTINEL_VALUE)
    return keys, values, take


# --------------------------------------------------------------- compaction
@functools.partial(jax.jit, static_argnames=("n_real", "out_size"))
def compact_sorted(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    rank_to_bfs: jax.Array,
    n_real: int,
    delta: DeltaBuffer,
    out_size: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Merge snapshot + buffer into one sorted device view (pure jnp, jit).

    Returns ``(sorted_keys (out_size,), sorted_values, count)`` with
    sentinel padding past ``count``.  The merge is searchsorted/prefix-sum
    rank arithmetic -- the device twin of ``bulk_insert``'s two-pointer
    merge: surviving old keys shift down by the tombstones below them and
    up by the new upserts below them; live buffer entries land at their
    (ingest-time) tree rank adjusted the same way.  ``out_size`` must be
    >= n_real + capacity (the static worst case).
    """
    sk = tree_keys[rank_to_bfs]
    sv = tree_values[rank_to_bfs]
    n = sk.shape[0]

    live = delta.keys != tree_lib.SENTINEL_KEY
    pres = live & ~delta.tombstone
    # old ranks shadowed by a buffer entry (tombstoned OR overwritten)
    shadow_idx = jnp.where(live & delta.in_tree, delta.tree_rank, n)
    shadowed = (
        jnp.zeros((n + 1,), bool).at[shadow_idx].set(True, mode="drop")[:n]
    )
    real_old = jnp.arange(n) < n_real
    keep_old = real_old & ~shadowed

    pres_i = pres.astype(jnp.int32)
    pres_cum = jnp.cumsum(pres_i)
    pres_prefix = jnp.concatenate([jnp.zeros((1,), jnp.int32), pres_cum])
    # live upserts strictly below each old key (old keys never equal a
    # SURVIVING buffer key: equal keys are shadowed)
    pres_below_old = pres_prefix[jnp.searchsorted(delta.keys, sk, side="left")]
    pos_old = (jnp.cumsum(keep_old) - keep_old) + pres_below_old

    shadow_prefix = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(shadowed.astype(jnp.int32))]
    )
    kept_below_entry = delta.tree_rank - shadow_prefix[delta.tree_rank]
    pos_new = kept_below_entry + (pres_cum - pres_i)

    def scatter(values_old, values_new, fill):
        out = jnp.full((out_size + 1,), fill, jnp.int32)
        po = jnp.where(keep_old, pos_old, out_size).astype(jnp.int32)
        out = out.at[po].set(values_old, mode="drop")
        pn = jnp.where(pres, pos_new, out_size).astype(jnp.int32)
        return out.at[pn].set(values_new, mode="drop")[:out_size]

    out_k = scatter(sk, delta.keys, tree_lib.SENTINEL_KEY)
    out_v = scatter(sv, delta.values, tree_lib.SENTINEL_VALUE)
    count = (jnp.sum(keep_old) + jnp.sum(pres)).astype(jnp.int32)
    return out_k, out_v, count


def compact(tree: TreeData, delta: DeltaBuffer) -> TreeData:
    """Absorb the buffer into a fresh perfect snapshot (DESIGN.md §7).

    Device work end to end -- sorted merge + Eytzinger re-layout are both
    jitted gathers -- except the single scalar sync that reads the new key
    count (it fixes the new snapshot's static height).
    """
    rank_to_bfs = jnp.asarray(tree_lib.rank_to_bfs_indices(tree.height))
    out_size = tree.n_real + delta.capacity
    sk, sv, count = compact_sorted(
        tree.keys, tree.values, rank_to_bfs, tree.n_real, delta, out_size
    )
    # The write path's ONE sanctioned host sync, per compaction: counted by
    # the runtime gate, allowlisted under lint rule ANA006 (DESIGN.md §10).
    n_real = int(analysis_runtime.device_fetch(count))
    if n_real == 0:
        raise ValueError("compaction would empty the tree")
    return tree_lib.layout_from_sorted_device(sk, sv, n_real)
