"""Cycle-accurate model of the paper's FPGA pipeline (faithful reproduction).

This module reproduces the paper's *own evaluation methodology*: count the
clock cycles needed to drain a stream of keys through each implementation
(Hrz, Dup4, Dup8, Hyb4, Hyb4q, Hyb8, Hyb8q) and report throughput relative to
the Hrz baseline (paper Fig. 7).

Model, mapped 1:1 from §II:

* BRAM partitions are dual-port  ->  each tree (or subtree) admits at most
  ``PORTS = 2`` new keys per cycle into its level pipeline.
* Horizontal partitioning makes each tree a depth-(h+1) pipeline: once keys
  are admitted they never conflict again; total time = last admission cycle
  + pipeline latency.
* ``Hrz``  : one tree, 2 keys/cycle, no stalls.
* ``DupN`` : N replica trees, 2N keys/cycle, no stalls, N x memory.
* ``HybN`` : top ``log2(N)`` levels in registers (no port limit; a whole
  chunk of ``2N`` keys traverses them simultaneously), N vertical subtrees
  below.  Keys found in registers finish immediately; survivors are routed to
  their subtree's buffer (capacity ``2N``, the paper's configuration).  A
  subtree admits up to 2 buffered keys per cycle.  If any key of the incoming
  chunk cannot be buffered, the frontend STALLS: no new chunk enters until
  every pending key is placed (paper §II.C.3).
  - direct mapping: key with chunk index i may only use slot i; each cycle the
    two ports fetch the two earliest occupied slots ("the key which comes
    earlier in the buffer is selected", §II.C.3 / Fig. 5).
  - queue mapping: per-buffer read/write pointers; keys pack densely at
    write_ptr + label where label counts earlier same-destination keys in the
    chunk (paper Fig. 6).

Stall accounting: a cycle is stalled exactly when the frontend cannot fetch
a new chunk because keys of the previous chunk are still waiting for buffer
slots after that cycle's placement pass.  The cycle a chunk enters is never
a stall (a fetch happened), and the cycle its last deferred key places is
not either -- the frontend resumes and fetches the next chunk in the same
cycle.  Both mappings share one placement rule and one departure rate
(<= PORTS buffered keys per subtree per cycle, drainable the cycle after
they are written), so queue vs direct differ only in which slot a key may
occupy -- the paper's actual distinction.

The simulator is plain NumPy/Python on purpose: it is a *model checker* for
the hardware semantics, not a performance path.  The performance path is
core/engine.py + kernels/.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.core import tree as tree_lib
from repro.core.engine import EngineConfig

PORTS = 2  # dual-port BRAM


@dataclasses.dataclass
class SimResult:
    name: str
    n_keys: int
    cycles: int
    stall_cycles: int
    keys_per_cycle: float
    memory_nodes: int
    pipeline_latency: int

    def speedup_vs(self, base: "SimResult") -> float:
        return base.cycles / self.cycles


def route_to_subtrees(
    tree: tree_lib.TreeData, keys: np.ndarray, register_levels: int
) -> np.ndarray:
    """Destination subtree for each key, -1 if resolved inside registers."""
    dest, _, found = tree_lib.register_layer_route(
        tree, np.asarray(keys, dtype=np.int32), register_levels
    )
    dest = np.array(dest, copy=True)
    dest[np.asarray(found)] = -1
    return dest


def simulate(
    config: EngineConfig,
    tree: tree_lib.TreeData,
    keys: np.ndarray,
    max_cycles: Optional[int] = None,
) -> SimResult:
    keys = np.asarray(keys, dtype=np.int32)
    K = keys.size
    h = tree.height
    if config.strategy == "hrz":
        cycles = math.ceil(K / PORTS) + (h + 1)
        return SimResult(config.name, K, cycles, 0, K / cycles, tree.n_nodes, h + 1)
    if config.strategy == "dup":
        n = config.n_trees
        cycles = math.ceil(K / (PORTS * n)) + (h + 1)
        return SimResult(
            config.name, K, cycles, 0, K / cycles, tree.n_nodes * n, h + 1
        )
    if config.strategy != "hyb":
        raise ValueError(config.strategy)
    return _simulate_hybrid(config, tree, keys, max_cycles)


def _simulate_hybrid(
    config: EngineConfig,
    tree: tree_lib.TreeData,
    keys: np.ndarray,
    max_cycles: Optional[int],
) -> SimResult:
    N = config.n_trees
    reg_levels = int(math.log2(N))
    chunk = PORTS * N  # keys fetched per cycle == max searchable in parallel
    capacity = chunk  # paper: buffer size == that maximum (Hyb4->8, Hyb8->16)
    K = keys.size
    h = tree.height
    sub_h = h - reg_levels
    # latency: reg_levels register compares + subtree pipeline + result cycle
    latency = reg_levels + (sub_h + 1)
    if max_cycles is None:
        max_cycles = 64 * (math.ceil(K / PORTS) + latency) + 1024

    dest_all = route_to_subtrees(tree, keys, max(reg_levels, 1))
    if reg_levels == 0:
        dest_all = np.zeros(K, dtype=np.int64)

    queue_mode = config.mapping == "queue"
    # Buffer state.
    if queue_mode:
        counts = np.zeros(N, dtype=np.int64)  # occupancy per circular queue
    else:
        occupied = np.zeros((N, capacity), dtype=bool)

    next_key = 0  # stream position
    pending: list[tuple[int, int]] = []  # [(chunk_index, dest)] awaiting slots
    admitted = 0  # keys admitted into subtree pipelines (or done in regs)
    last_admit_cycle = 0
    stall_cycles = 0
    cycle = 0

    def try_place(ci: int, d: int) -> bool:
        """One buffer-write attempt -- the ONE placement rule both mappings
        share, so queue and direct mode cannot drift apart in admission
        semantics (they differ only in which slot a key may occupy)."""
        if queue_mode:
            if counts[d] < capacity:
                counts[d] += 1
                return True
            return False
        if not occupied[d, ci]:
            occupied[d, ci] = True
            return True
        return False

    while admitted < K:
        cycle += 1
        if cycle > max_cycles:
            raise RuntimeError(f"{config.name}: no convergence in {max_cycles} cycles")
        # ---- 1) subtree ports drain buffers (2 keys per subtree per cycle).
        # Departure semantics are identical across mappings: every subtree
        # admits at most PORTS buffered keys per cycle, and keys written in
        # this cycle's frontend pass (steps 2/3) become drainable next
        # cycle -- the queue path decrements aggregate occupancy, the
        # direct path clears the two earliest occupied slots ("the key
        # which comes earlier in the buffer is selected", paper §II.C.3),
        # but the per-cycle departure count is the same.
        if queue_mode:
            drained = np.minimum(counts, PORTS)
            admitted += int(drained.sum())
            if drained.sum():
                last_admit_cycle = cycle
            counts -= drained
        else:
            for s in range(N):
                occ = occupied[s]
                nz = np.flatnonzero(occ)
                take = nz[:PORTS]
                if take.size:
                    occ[take] = False
                    admitted += int(take.size)
                    last_admit_cycle = cycle
        # ---- 2) frontend: place pending keys first.  A cycle is a STALL
        # exactly when the frontend cannot fetch a new chunk because keys
        # are still waiting for buffer slots after this pass (paper
        # §II.C.3: "fetching [the] new chunk stalls until all the keys of
        # the current chunk are stored").  The entry cycle itself is NOT a
        # stall -- a chunk was fetched then -- and the cycle in which the
        # last pending key places is not either: the frontend resumes and
        # fetches the next chunk in the same cycle (fall through below).
        # Counting the entry cycle AND the blocked passes double-booked
        # every deferral episode by one cycle of both stall and latency.
        if pending:
            pending = [(ci, d) for ci, d in pending if not try_place(ci, d)]
            if pending:
                stall_cycles += 1
                continue  # frontend blocked: no fetch this cycle
        # ---- 3) new chunk enters the register layer
        if next_key >= K:
            continue
        hi = min(next_key + chunk, K)
        idxs = np.arange(next_key, hi)
        dests = dest_all[idxs]
        next_key = hi
        # register hits complete without touching buffers
        reg_hits = int((dests < 0).sum())
        if reg_hits:
            admitted += reg_hits
            last_admit_cycle = cycle
        pending = [
            (int(ci), int(d))
            for ci, d in zip(range(len(idxs)), dests)
            if d >= 0 and not try_place(int(ci), int(d))
        ]

    cycles = last_admit_cycle + latency
    return SimResult(
        config.name,
        K,
        cycles,
        stall_cycles,
        K / cycles,
        tree.n_nodes,
        latency,
    )


def run_paper_matrix(
    tree: tree_lib.TreeData,
    key_sets: Dict[str, np.ndarray],
    configs: Optional[Dict[str, EngineConfig]] = None,
) -> Dict[str, Dict[str, SimResult]]:
    """The paper's full evaluation grid: {keyset: {impl: SimResult}}."""
    from repro.core.engine import PAPER_CONFIGS

    configs = configs or PAPER_CONFIGS
    out: Dict[str, Dict[str, SimResult]] = {}
    for set_name, keys in key_sets.items():
        row = {}
        for impl, cfg in configs.items():
            row[impl] = simulate(cfg, tree, keys)
        out[set_name] = row
    return out
