"""Core library: the paper's BST accelerator, TPU-native.

Two planes (see DESIGN.md §3):
  * cycle-accurate reproduction of the FPGA semantics -> ``cyclesim``
  * high-performance JAX/Pallas engine               -> ``engine``/``distributed``
"""

from repro.core.buffers import (
    DispatchPlan,
    combine_to_chunk,
    direct_dispatch,
    dispatch,
    gather_from_buffers,
    queue_dispatch,
)
from repro.core.cyclesim import SimResult, run_paper_matrix, simulate
from repro.core.distributed import make_distributed_lookup, make_dup_lookup
from repro.core.engine import PAPER_CONFIGS, BSTEngine, EngineConfig
from repro.core.plans import SearchPlan, execute_plan, make_plan
from repro.core.tree import (
    SENTINEL_KEY,
    SENTINEL_VALUE,
    TreeData,
    build_tree,
    search_reference,
)
from repro.core.updates import bulk_delete, bulk_insert, sorted_view

__all__ = [
    "BSTEngine",
    "DispatchPlan",
    "EngineConfig",
    "PAPER_CONFIGS",
    "SENTINEL_KEY",
    "SENTINEL_VALUE",
    "SearchPlan",
    "SimResult",
    "TreeData",
    "build_tree",
    "combine_to_chunk",
    "direct_dispatch",
    "dispatch",
    "execute_plan",
    "gather_from_buffers",
    "make_distributed_lookup",
    "make_plan",
    "make_dup_lookup",
    "queue_dispatch",
    "run_paper_matrix",
    "search_reference",
    "simulate",
]
