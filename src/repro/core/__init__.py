"""Core library: the paper's BST accelerator, TPU-native.

Two planes (see DESIGN.md §3):
  * cycle-accurate reproduction of the FPGA semantics -> ``cyclesim``
  * high-performance JAX/Pallas engine               -> ``engine``/``distributed``
"""

from repro.core.buffers import (
    DispatchPlan,
    combine_to_chunk,
    direct_dispatch,
    dispatch,
    gather_from_buffers,
    queue_dispatch,
)
from repro.core.cyclesim import SimResult, run_paper_matrix, simulate
from repro.core.delta import DeltaBuffer
from repro.core.distributed import (
    make_distributed_lookup,
    make_distributed_query,
    make_dup_lookup,
    make_dup_query,
)
from repro.core.engine import PAPER_CONFIGS, BSTEngine, EngineConfig
from repro.core.plans import (
    QUERY_OPS,
    RANGE_OPS,
    SearchPlan,
    execute_plan,
    execute_plan_ordered,
    make_plan,
    ordered_query,
)
from repro.core.tree import (
    NO_PRED_KEY,
    NO_SUCC_KEY,
    SENTINEL_KEY,
    SENTINEL_VALUE,
    OrderedResult,
    TreeData,
    build_tree,
    search_reference,
    search_reference_ordered,
)
from repro.core.updates import bulk_delete, bulk_insert, sorted_view

__all__ = [
    "BSTEngine",
    "DeltaBuffer",
    "DispatchPlan",
    "EngineConfig",
    "NO_PRED_KEY",
    "NO_SUCC_KEY",
    "OrderedResult",
    "PAPER_CONFIGS",
    "QUERY_OPS",
    "RANGE_OPS",
    "SENTINEL_KEY",
    "SENTINEL_VALUE",
    "SearchPlan",
    "SimResult",
    "TreeData",
    "build_tree",
    "combine_to_chunk",
    "direct_dispatch",
    "dispatch",
    "execute_plan",
    "execute_plan_ordered",
    "gather_from_buffers",
    "make_distributed_lookup",
    "make_distributed_query",
    "make_plan",
    "make_dup_lookup",
    "make_dup_query",
    "ordered_query",
    "queue_dispatch",
    "run_paper_matrix",
    "search_reference",
    "search_reference_ordered",
    "simulate",
]
