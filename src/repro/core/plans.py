"""SearchPlan: one strategy abstraction behind every search datapath.

The paper's claim is a single compare-descend datapath *reconfigured* by
partitioning strategy (horizontal / duplicated / hybrid).  This module is
that datapath in software (DESIGN.md §4): a ``SearchPlan`` captures the
strategy's static layout (flat forest operands, register layer, dispatch
mapping) and the four pipeline phases

    route_phase    -- register-layer descent, survivors get a subtree id
    dispatch_phase -- direct-/queue-mapped buffer placement (paper §II.C.3)
    descend_phase  -- forest-batched subtree descent (Pallas kernel or oracle)
    combine_phase  -- scatter buffered results back into chunk order

are plain functions shared by BOTH drivers: the single-chip ``BSTEngine``
and the multi-chip ``all_to_all`` engine in ``core/distributed.py``.  The
drivers differ only in what sits between the phases (nothing, or a pair of
collectives) -- exactly the FPGA situation, where one datapath serves every
BRAM partitioning.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import buffers as buf
from repro.core import tree as tree_lib
from repro.core.tree import TreeData
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class SearchPlan:
    """Static per-engine search configuration (built once, looked up often).

    forest_keys/forest_values: (n_rows, m) flat level-major (sub)trees --
    the single tree for hrz/dup (n_rows == 1), one row per vertical subtree
    for hyb.  ``shared_tree`` marks dup's replication-without-copy: every
    kernel grid row reads operand row 0.  ``split_level > 0`` enables the
    register-layer route -> buffer dispatch pipeline (hyb); ``full_tree``
    is the stall-round oracle for overflowed keys.
    """

    strategy: str  # hrz | dup | hyb
    forest_keys: jax.Array
    forest_values: jax.Array
    forest_height: int
    n_trees: int
    shared_tree: bool
    split_level: int = 0
    mapping: str = "queue"  # direct | queue (hyb only)
    buffer_slack: float = 2.0
    reg_keys: Optional[jax.Array] = None
    reg_values: Optional[jax.Array] = None
    full_tree: Optional[TreeData] = None

    def memory_nodes(self) -> int:
        """Stored nodes (the paper's Fig. 8 memory metric)."""
        rows, m = self.forest_keys.shape
        if self.strategy == "dup":
            return int(m) * self.n_trees
        reg = 0 if self.reg_keys is None else int(self.reg_keys.shape[0])
        return rows * int(m) + reg


def resolved_register_levels(n_trees: int, register_levels: Optional[int]) -> int:
    if register_levels is not None:
        return register_levels
    return max(1, int(math.log2(max(n_trees, 2))))


def make_plan(
    tree: TreeData,
    *,
    strategy: str,
    n_trees: int = 1,
    mapping: str = "queue",
    register_levels: Optional[int] = None,
    buffer_slack: float = 2.0,
) -> SearchPlan:
    """Build the strategy's SearchPlan from one immutable tree snapshot."""
    if strategy == "hrz":
        return SearchPlan(
            strategy="hrz",
            forest_keys=tree.keys[None, :],
            forest_values=tree.values[None, :],
            forest_height=tree.height,
            n_trees=1,
            shared_tree=False,
        )
    if strategy == "dup":
        if n_trees < 1:
            raise ValueError("dup needs n_trees >= 1")
        return SearchPlan(
            strategy="dup",
            forest_keys=tree.keys[None, :],
            forest_values=tree.values[None, :],
            forest_height=tree.height,
            n_trees=n_trees,
            shared_tree=True,
        )
    if strategy != "hyb":
        raise ValueError(f"unknown strategy {strategy!r}")

    r = resolved_register_levels(n_trees, register_levels)
    if (1 << r) < n_trees:
        raise ValueError(
            f"register_levels={r} exposes {1 << r} subtrees < n_trees={n_trees}"
        )
    if r > tree.height:
        raise ValueError("register layer deeper than the tree")
    split_level = int(math.log2(n_trees))
    if (1 << split_level) != n_trees:
        raise ValueError("n_trees must be a power of two")
    # Register layer = levels [0, split_level); subtrees hang below.
    idx = tree_lib.all_subtree_gather_indices(tree.height, split_level)
    reg_n = (1 << max(split_level, 1)) - 1
    return SearchPlan(
        strategy="hyb",
        forest_keys=tree.keys[jnp.asarray(idx)],
        forest_values=tree.values[jnp.asarray(idx)],
        forest_height=tree.height - split_level,
        n_trees=n_trees,
        shared_tree=False,
        split_level=split_level,
        mapping=mapping,
        buffer_slack=buffer_slack,
        reg_keys=tree.keys[:reg_n],
        reg_values=tree.values[:reg_n],
        full_tree=tree,
    )


# --------------------------------------------------------------------- phases
def route_phase(
    reg_keys: jax.Array,
    reg_values: jax.Array,
    queries: jax.Array,
    split_level: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Register-layer descent -> (dest, value, found).

    ``split_level == 0`` means no routing network: everything goes to
    subtree 0 unresolved (the single-partition degenerate case).
    """
    B = queries.shape[0]
    if split_level == 0:
        return (
            jnp.zeros((B,), jnp.int32),
            jnp.full((B,), tree_lib.SENTINEL_VALUE, jnp.int32),
            jnp.zeros((B,), bool),
        )
    reg_tree = TreeData(
        reg_keys, reg_values, max(split_level - 1, 0), int(reg_keys.shape[0])
    )
    return tree_lib.register_layer_route(reg_tree, queries, split_level)


def dispatch_phase(
    mapping: str,
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    active: Optional[jax.Array] = None,
) -> buf.DispatchPlan:
    """Buffer placement: the paper's direct/queue mapping networks."""
    return buf.dispatch(mapping, dest, n_dest, capacity, active=active)


def gather_phase(
    items: jax.Array, dplan: buf.DispatchPlan, fill_value=0
) -> Tuple[jax.Array, jax.Array]:
    """Materialize the buffered items: (B,) -> ((n_dest, cap), live mask)."""
    per_dest = buf.gather_from_buffers(items, dplan.buffers, fill_value=fill_value)
    return per_dest, dplan.buffers >= 0


def descend_phase(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    height: int,
    queries: jax.Array,
    active: Optional[jax.Array] = None,
    *,
    shared_tree: bool = False,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Forest-batched compare-descend: (n_trees, B) queries in one shot.

    ``use_kernel=True`` lowers to the single forest ``pallas_call``;
    otherwise the vmapped jnp oracle runs (bit-identical by property test).
    Both paths live behind ``kernels.ops.bst_search_forest`` so the
    forest-batching shape handling exists exactly once.
    """
    return kops.bst_search_forest(
        forest_keys,
        forest_values,
        queries,
        height=height,
        active=active,
        interpret=interpret,
        shared_tree=shared_tree,
        use_ref=not use_kernel,
    )


def combine_phase(
    sub_values: jax.Array,
    sub_found: jax.Array,
    dplan: buf.DispatchPlan,
    chunk_size: int,
    reg_values: Optional[jax.Array] = None,
    reg_found: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter per-buffer results back to chunk order; merge register hits."""
    got_v = buf.combine_to_chunk(
        sub_values, dplan.buffers, chunk_size, fill_value=tree_lib.SENTINEL_VALUE
    )
    got_f = buf.combine_to_chunk(sub_found, dplan.buffers, chunk_size, fill_value=False)
    if reg_found is None:
        return got_v, got_f
    return jnp.where(reg_found, reg_values, got_v), reg_found | got_f


# -------------------------------------------------------------------- drivers
def execute_plan(
    plan: SearchPlan,
    queries: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """The single-chip driver: run a query chunk through the plan's phases."""
    B = queries.shape[0]
    if plan.strategy == "hrz":
        val, found = descend_phase(
            plan.forest_keys,
            plan.forest_values,
            plan.forest_height,
            queries[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
        )
        return val[0], found[0]

    if plan.strategy == "dup":
        # n_trees replicas each take a contiguous slice of the chunk.
        n = plan.n_trees
        pad = (-B) % n
        q = jnp.pad(queries, (0, pad)).reshape(n, -1)
        val, found = descend_phase(
            plan.forest_keys,
            plan.forest_values,
            plan.forest_height,
            q,
            shared_tree=True,
            use_kernel=use_kernel,
            interpret=interpret,
        )
        return val.reshape(-1)[:B], found.reshape(-1)[:B]

    # hyb: route -> dispatch -> descend -> combine (+ stall round).
    dest, reg_val, reg_found = route_phase(
        plan.reg_keys, plan.reg_values, queries, plan.split_level
    )
    active = ~reg_found
    capacity = int(math.ceil(B / plan.n_trees * plan.buffer_slack))
    dplan = dispatch_phase(plan.mapping, dest, plan.n_trees, capacity, active=active)
    per_sub_q, per_sub_active = gather_phase(queries, dplan)
    sub_vals, sub_found = descend_phase(
        plan.forest_keys,
        plan.forest_values,
        plan.forest_height,
        per_sub_q,
        per_sub_active,
        use_kernel=use_kernel,
        interpret=interpret,
    )
    val, found = combine_phase(sub_vals, sub_found, dplan, B, reg_val, reg_found)

    def retry(args):
        # Stall round: the overflowed minority re-descends the whole tree --
        # the software analogue of the frontend stall while buffers drain.
        val, found = args
        r_val, r_found = tree_lib.search_reference(plan.full_tree, queries)
        val = jnp.where(dplan.overflow, r_val, val)
        found = jnp.where(dplan.overflow, r_found, found)
        return val, found

    return jax.lax.cond(jnp.any(dplan.overflow), retry, lambda a: a, (val, found))
