"""SearchPlan: one strategy abstraction behind every search datapath.

The paper's claim is a single compare-descend datapath *reconfigured* by
partitioning strategy (horizontal / duplicated / hybrid).  This module is
that datapath in software (DESIGN.md §4): a ``SearchPlan`` captures the
strategy's static layout (flat forest operands, register layer, dispatch
mapping) and the pipeline phases

    route_phase    -- register-layer descent, survivors get a subtree id
    dispatch_phase -- direct-/queue-mapped buffer placement (paper §II.C.3)
    descend_phase  -- forest-batched subtree descent (Pallas kernel or oracle)
    combine_phase  -- scatter buffered results back into chunk order

are plain functions.  Since DESIGN.md §8 the single-chip driver no longer
composes them: every strategy -- hyb included -- lowers straight through
the one forest call (``_hybrid_descend`` selects the kernel's dispatch
configuration, so route/dispatch/descent/stall-replay/delta all run inside
the ``pallas_call`` or its jnp twin).  The phase functions remain the
shared vocabulary of the drivers whose dispatch crosses a real boundary:
the multi-chip ``all_to_all`` engine in ``core/distributed.py`` (a pair of
collectives between dispatch and descent) and the roofline lowering in
``launch/dryrun_bst.py``.

The datapath is ORDERED (DESIGN.md §6): every phase has an ``_ordered``
variant carrying the full ``OrderedResult`` (exact match + strict
predecessor/successor ancestors + rank boundary), and ``ordered_query``
is the per-op contract every engine lowers through -- lookup, predecessor,
successor, range_count and range_scan all ride the SAME single
forest-batched ``pallas_call`` (range ops descend ``lo || hi`` in one
concatenated pass and finish with rank arithmetic over the sorted view).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import invariants
from repro.core import buffers as buf
from repro.core import delta as delta_lib
from repro.core import tree as tree_lib
from repro.core.tree import OrderedResult, TreeData
from repro.kernels import ops as kops

# The per-op query contract (DESIGN.md §6).  Every op lowers through one
# ordered forest descent; they differ only in operand count and epilogue.
QUERY_OPS = ("lookup", "predecessor", "successor", "range_count", "range_scan")
RANGE_OPS = ("range_count", "range_scan")

# How each engine strategy lays out over a serving mesh (DESIGN.md §9):
#   hrz -- the one tree vertically partitioned into per-device subtrees;
#          request chunks route through the stall-free all_to_all network;
#   dup -- the tree replicated on every device, the chunk split over the
#          axis (data parallelism, no routing traffic at all);
#   hyb -- subtree-sharded forest + replicated register layer, with the
#          paper's queue-capped dispatch buffers as the collective-bytes
#          lever (finite capacity + stall rounds).
# ``mesh_axis_for_strategy`` is the single place that mapping lives, so the
# server, the benchmarks and the examples cannot disagree on which mesh
# axis a strategy shards over.
SHARDED_STRATEGIES = ("hrz", "dup", "hyb")


def mesh_axis_for_strategy(strategy: str) -> str:
    """The mesh axis a sharded plan uses: dup shards the *batch* over the
    data axis; hrz/hyb shard the *tree* over the model axis."""
    if strategy not in SHARDED_STRATEGIES:
        raise ValueError(
            f"unknown sharded strategy {strategy!r} (want {SHARDED_STRATEGIES})"
        )
    return "data" if strategy == "dup" else "model"


def validate_op(op: str, has_hi: bool) -> None:
    """One place for the op-name / operand-arity contract checks -- shared
    by every query entry point (engine, distributed, plans)."""
    if op not in QUERY_OPS:
        raise ValueError(f"unknown op {op!r} (want one of {QUERY_OPS})")
    if has_hi != (op in RANGE_OPS):
        raise ValueError(f"op {op!r}: range ops take (lo, hi), others one batch")


@dataclasses.dataclass(frozen=True)
class SearchPlan:
    """Static per-engine search configuration (built once, looked up often).

    forest_keys/forest_values: (n_rows, m) flat level-major trees -- one
    row for every single-chip strategy: hrz and hyb carry the full tree
    (for hyb, levels [0, split_level) double as the register layer and
    each vertical subtree is a BRAM slice of the same flat image --
    DESIGN.md §8), dup shares its one row across replicas.
    ``shared_tree`` marks dup's replication-without-copy: every kernel
    grid row reads operand row 0.  ``split_level``/``mapping``/
    ``buffer_slack`` parameterize hyb's in-kernel dispatch (paper
    §II.C.3).  ``full_tree`` (every strategy) backs the ordered ops'
    sorted-view gathers; ``rank_to_bfs`` maps in-order rank -> BFS index
    so range_scan reads consecutive ranks straight out of the flat layout
    (the delta epilogues' sorted view is the same gather, traced on demand
    inside ``ordered_query`` so read-only plans never materialize it).
    ``reg_keys``/``reg_values`` remain only for multi-chip drivers that
    replicate the register layer explicitly (``core/distributed.py``
    builds its own; single-chip hyb reads it out of the flat operand).
    """

    strategy: str  # hrz | dup | hyb
    forest_keys: jax.Array
    forest_values: jax.Array
    forest_height: int
    n_trees: int
    shared_tree: bool
    split_level: int = 0
    mapping: str = "queue"  # direct | queue (hyb only)
    buffer_slack: float = 2.0
    reg_keys: Optional[jax.Array] = None
    reg_values: Optional[jax.Array] = None
    full_tree: Optional[TreeData] = None
    rank_to_bfs: Optional[jax.Array] = None

    def sorted_view(self) -> Tuple[jax.Array, jax.Array]:
        """The snapshot's sorted key/value view (one gather; under ``jit``
        both inputs are constants, so XLA folds it at compile time)."""
        return (
            self.full_tree.keys[self.rank_to_bfs],
            self.full_tree.values[self.rank_to_bfs],
        )

    def memory_nodes(self) -> int:
        """Stored nodes (the paper's Fig. 8 memory metric)."""
        rows, m = self.forest_keys.shape
        if self.strategy == "dup":
            return int(m) * self.n_trees
        reg = 0 if self.reg_keys is None else int(self.reg_keys.shape[0])
        return rows * int(m) + reg


def resolved_register_levels(n_trees: int, register_levels: Optional[int]) -> int:
    if register_levels is not None:
        return register_levels
    return max(1, int(math.log2(max(n_trees, 2))))


def make_plan(
    tree: TreeData,
    *,
    strategy: str,
    n_trees: int = 1,
    mapping: str = "queue",
    register_levels: Optional[int] = None,
    buffer_slack: float = 2.0,
) -> SearchPlan:
    """Build the strategy's SearchPlan from one immutable tree snapshot."""
    rank_to_bfs = jnp.asarray(tree_lib.rank_to_bfs_indices(tree.height))
    if strategy == "hrz":
        return SearchPlan(
            strategy="hrz",
            forest_keys=tree.keys[None, :],
            forest_values=tree.values[None, :],
            forest_height=tree.height,
            n_trees=1,
            shared_tree=False,
            full_tree=tree,
            rank_to_bfs=rank_to_bfs,
        )
    if strategy == "dup":
        if n_trees < 1:
            raise ValueError("dup needs n_trees >= 1")
        return SearchPlan(
            strategy="dup",
            forest_keys=tree.keys[None, :],
            forest_values=tree.values[None, :],
            forest_height=tree.height,
            n_trees=n_trees,
            shared_tree=True,
            full_tree=tree,
            rank_to_bfs=rank_to_bfs,
        )
    if strategy != "hyb":
        raise ValueError(f"unknown strategy {strategy!r}")

    r = resolved_register_levels(n_trees, register_levels)
    if (1 << r) < n_trees:
        raise ValueError(
            f"register_levels={r} exposes {1 << r} subtrees < n_trees={n_trees}"
        )
    if r > tree.height:
        raise ValueError("register layer deeper than the tree")
    split_level = invariants.split_level_for(n_trees)
    # One flat operand carries the whole pipeline (DESIGN.md §8): levels
    # [0, split_level) double as the register layer and each vertical
    # subtree is a BRAM slice of the same level-major image, so the hybrid
    # kernel (and its jnp twin) needs no per-subtree gather at build time.
    return SearchPlan(
        strategy="hyb",
        forest_keys=tree.keys[None, :],
        forest_values=tree.values[None, :],
        forest_height=tree.height,
        n_trees=n_trees,
        shared_tree=False,
        split_level=split_level,
        mapping=mapping,
        buffer_slack=buffer_slack,
        full_tree=tree,
        rank_to_bfs=rank_to_bfs,
    )


# --------------------------------------------------------------------- phases
def route_phase(
    reg_keys: jax.Array,
    reg_values: jax.Array,
    queries: jax.Array,
    split_level: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Register-layer descent -> (dest, value, found).

    ``split_level == 0`` means no routing network: everything goes to
    subtree 0 unresolved (the single-partition degenerate case).
    """
    B = queries.shape[0]
    if split_level == 0:
        return (
            jnp.zeros((B,), jnp.int32),
            jnp.full((B,), tree_lib.SENTINEL_VALUE, jnp.int32),
            jnp.zeros((B,), bool),
        )
    reg_tree = TreeData(
        reg_keys, reg_values, max(split_level - 1, 0), int(reg_keys.shape[0])
    )
    return tree_lib.register_layer_route(reg_tree, queries, split_level)


def route_phase_ordered(
    reg_keys: jax.Array,
    reg_values: jax.Array,
    queries: jax.Array,
    split_level: int,
    full_height: int,
) -> Tuple[jax.Array, OrderedResult]:
    """Ordered register-layer descent -> (dest, partial OrderedResult).

    The partial result carries the register layer's predecessor/successor
    candidates and its rank contribution (left-subtree sizes of the FULL
    tree); the subtree descent below the split completes all three
    (``merge_ordered``).
    """
    B = queries.shape[0]
    if split_level == 0:
        return jnp.zeros((B,), jnp.int32), tree_lib.init_ordered(B)
    reg_tree = TreeData(
        reg_keys, reg_values, max(split_level - 1, 0), int(reg_keys.shape[0])
    )
    return tree_lib.register_layer_route_ordered(
        reg_tree, queries, split_level, full_height
    )


def dispatch_phase(
    mapping: str,
    dest: jax.Array,
    n_dest: int,
    capacity: int,
    active: Optional[jax.Array] = None,
) -> buf.DispatchPlan:
    """Buffer placement: the paper's direct/queue mapping networks."""
    return buf.dispatch(mapping, dest, n_dest, capacity, active=active)


def gather_phase(
    items: jax.Array, dplan: buf.DispatchPlan, fill_value=0
) -> Tuple[jax.Array, jax.Array]:
    """Materialize the buffered items: (B,) -> ((n_dest, cap), live mask)."""
    per_dest = buf.gather_from_buffers(items, dplan.buffers, fill_value=fill_value)
    return per_dest, dplan.buffers >= 0


def descend_phase(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    height: int,
    queries: jax.Array,
    active: Optional[jax.Array] = None,
    *,
    shared_tree: bool = False,
    use_kernel: bool = False,
    interpret: bool = True,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Forest-batched compare-descend: (n_trees, B) queries in one shot.

    ``use_kernel=True`` lowers to the single forest ``pallas_call``;
    otherwise the vmapped jnp oracle runs (bit-identical by property test).
    Both paths live behind ``kernels.ops.bst_search_forest`` so the
    forest-batching shape handling exists exactly once.  ``delta`` rides
    the write buffer's flat operands on either path (DESIGN.md §7).
    """
    return kops.bst_search_forest(
        forest_keys,
        forest_values,
        queries,
        height=height,
        active=active,
        interpret=interpret,
        shared_tree=shared_tree,
        use_ref=not use_kernel,
        delta=delta,
    )


def descend_phase_ordered(
    forest_keys: jax.Array,
    forest_values: jax.Array,
    height: int,
    queries: jax.Array,
    active: Optional[jax.Array] = None,
    *,
    shared_tree: bool = False,
    use_kernel: bool = False,
    interpret: bool = True,
    delta: Optional[Tuple[jax.Array, ...]] = None,
) -> OrderedResult:
    """Ordered forest-batched compare-descend (DESIGN.md §6).

    Same single-``pallas_call`` lowering as ``descend_phase``; the extra
    outputs (strict predecessor/successor ancestors, rank boundary) fall out
    of the same pipelined descent.  Fields are (n_trees, B).  With
    ``delta`` the write buffer rides the call and value/found/rank come
    back merged (DESIGN.md §7).
    """
    out = kops.bst_ordered_forest(
        forest_keys,
        forest_values,
        queries,
        height=height,
        active=active,
        interpret=interpret,
        shared_tree=shared_tree,
        use_ref=not use_kernel,
        delta=delta,
    )
    return OrderedResult(*out)


def combine_phase(
    sub_values: jax.Array,
    sub_found: jax.Array,
    dplan: buf.DispatchPlan,
    chunk_size: int,
    reg_values: Optional[jax.Array] = None,
    reg_found: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter per-buffer results back to chunk order; merge register hits."""
    got_v = buf.combine_to_chunk(
        sub_values, dplan.buffers, chunk_size, fill_value=tree_lib.SENTINEL_VALUE
    )
    got_f = buf.combine_to_chunk(sub_found, dplan.buffers, chunk_size, fill_value=False)
    if reg_found is None:
        return got_v, got_f
    return jnp.where(reg_found, reg_values, got_v), reg_found | got_f


def combine_phase_ordered(
    sub: OrderedResult, dplan: buf.DispatchPlan, chunk_size: int
) -> OrderedResult:
    """Scatter per-buffer ordered results back to chunk order.

    Unplaced lanes get each field's identity (no hit, no predecessor, no
    successor, rank 0), so a later ``merge_ordered`` / stall-round override
    composes cleanly.
    """
    fills = (
        tree_lib.SENTINEL_VALUE,  # value
        False,  # found
        tree_lib.NO_PRED_KEY,
        tree_lib.SENTINEL_VALUE,
        tree_lib.NO_SUCC_KEY,
        tree_lib.SENTINEL_VALUE,
        0,  # rank
    )
    return OrderedResult(
        *(
            buf.combine_to_chunk(field, dplan.buffers, chunk_size, fill_value=fill)
            for field, fill in zip(sub, fills)
        )
    )


def pack_ordered(res: OrderedResult) -> jax.Array:
    """Stack the 7 ordered fields into one ``(..., F)`` int32 image.

    The whole ordered payload then rides a routing collective as ONE
    ``all_to_all`` (or one device transfer) instead of a collective per
    field -- the packed-combine contract of DESIGN.md §9.  The lane width
    is pinned to ``invariants.ORDERED_PACK_WIDTH`` so a field added to
    ``OrderedResult`` cannot silently widen every collective.
    """
    assert len(res) == invariants.ORDERED_PACK_WIDTH, res._fields
    return jnp.stack([f.astype(jnp.int32) for f in res], axis=-1)


def unpack_ordered(packed: jax.Array) -> OrderedResult:
    # NamedTuple order on both sides keeps pack/unpack structurally tied.
    assert packed.shape[-1] == invariants.ORDERED_PACK_WIDTH, packed.shape
    fields = tuple(packed[..., i] for i in range(packed.shape[-1]))
    res = OrderedResult(*fields)
    return res._replace(found=res.found != 0)


def merge_ordered(reg: OrderedResult, sub: OrderedResult) -> OrderedResult:
    """Merge the register layer's partial result with the subtree descent.

    The two are disjoint halves of one root-to-leaf path, so: exact hits are
    exclusive; the predecessor is the deeper (larger) of the two right-turn
    candidates and the successor the deeper (smaller) left-turn candidate
    (absent candidates sit at the tracking identities, so plain max/min is
    exact); ranks add (register turns count FULL-tree left subtrees, subtree
    turns count local ones -- together the global rank, DESIGN.md §6).
    """
    take_sub_pred = sub.pred_key > reg.pred_key
    take_sub_succ = sub.succ_key < reg.succ_key
    return OrderedResult(
        value=jnp.where(reg.found, reg.value, sub.value),
        found=reg.found | sub.found,
        pred_key=jnp.maximum(reg.pred_key, sub.pred_key),
        pred_value=jnp.where(take_sub_pred, sub.pred_value, reg.pred_value),
        succ_key=jnp.minimum(reg.succ_key, sub.succ_key),
        succ_value=jnp.where(take_sub_succ, sub.succ_value, reg.succ_value),
        rank=reg.rank + sub.rank,
    )


def where_ordered(
    mask: jax.Array, a: OrderedResult, b: OrderedResult
) -> OrderedResult:
    """Per-lane select between two ordered results (stall-round override)."""
    return OrderedResult(*(jnp.where(mask, x, y) for x, y in zip(a, b)))


# -------------------------------------------------------------------- drivers
# The kernel dispatches each block_q chunk independently (the FPGA streams
# chunks); the jnp twin treats the whole batch as one chunk, the retired
# driver's granularity.  Results are identical either way -- the stall
# round's contract -- so the choice is purely a throughput model.
KERNEL_BLOCK_Q = 512


def hyb_capacity(plan: SearchPlan, chunk: int) -> int:
    """Per-subtree dispatch-buffer depth for a ``chunk``-lane frontend:
    the fair share ``chunk / n_trees`` scaled by the plan's slack."""
    return invariants.buffer_capacity(chunk, plan.n_trees, plan.buffer_slack)


def _hybrid_descend(
    plan: SearchPlan,
    queries: jax.Array,
    *,
    ordered: bool,
    use_kernel: bool,
    interpret: bool,
    delta: Optional[Tuple[jax.Array, ...]],
) -> Tuple[jax.Array, ...]:
    """Single-chip hyb: the WHOLE pipeline in one call (DESIGN.md §8).

    Register route, queue/direct dispatch, subtree descent, stall-round
    replay and delta resolution all execute inside the forest
    ``pallas_call`` (``use_kernel=True``) or its structurally matching jnp
    oracle -- there is no driver-level composition (and no driver-level
    delta twin) left to drift.
    """
    chunk = KERNEL_BLOCK_Q if use_kernel else queries.shape[0]
    return kops.bst_hybrid_forest(
        plan.full_tree.keys,
        plan.full_tree.values,
        queries,
        height=plan.full_tree.height,
        split_level=plan.split_level,
        mapping=plan.mapping,
        capacity=hyb_capacity(plan, chunk),
        block_q=KERNEL_BLOCK_Q,
        interpret=interpret,
        ordered=ordered,
        use_ref=not use_kernel,
        delta=delta,
    )


def execute_plan_ordered(
    plan: SearchPlan,
    queries: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
    delta: Optional[delta_lib.DeltaBuffer] = None,
) -> OrderedResult:
    """The single-chip driver: one ordered pass through the plan's phases.

    Returns the full per-query ``OrderedResult`` -- the common substrate
    every query op's epilogue reads (``ordered_query``).  All strategies
    descend through the one forest-batched kernel / oracle; hyb's route /
    dispatch / descent / stall replay execute inside that same call
    (DESIGN.md §8).

    With ``delta`` (DESIGN.md §7) value/found/rank come back merged
    against the pending write buffer.  Every strategy resolves the buffer
    inside the descent call itself -- the driver never composes a jnp
    twin on top.
    """
    B = queries.shape[0]
    d_ops = None if delta is None else delta_lib.operands(delta)
    if plan.strategy == "hrz":
        res = descend_phase_ordered(
            plan.forest_keys,
            plan.forest_values,
            plan.forest_height,
            queries[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
            delta=d_ops,
        )
        return OrderedResult(*(f[0] for f in res))

    if plan.strategy == "dup":
        # n_trees replicas each take a contiguous slice of the chunk.
        n = plan.n_trees
        pad = (-B) % n
        q = jnp.pad(queries, (0, pad)).reshape(n, -1)
        res = descend_phase_ordered(
            plan.forest_keys,
            plan.forest_values,
            plan.forest_height,
            q,
            shared_tree=True,
            use_kernel=use_kernel,
            interpret=interpret,
            delta=d_ops,
        )
        return OrderedResult(*(f.reshape(-1)[:B] for f in res))

    # hyb: route + dispatch + descent + stall replay + delta merge, all
    # inside the one forest call (DESIGN.md §8).
    return OrderedResult(
        *_hybrid_descend(
            plan,
            queries,
            ordered=True,
            use_kernel=use_kernel,
            interpret=interpret,
            delta=d_ops,
        )
    )


def execute_plan(
    plan: SearchPlan,
    queries: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
    delta: Optional[delta_lib.DeltaBuffer] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Membership lookup through the kernel's 2-output configuration.

    Same phase chain as ``execute_plan_ordered`` but none of the ordered
    tracking -- the hot lookup path pays nothing for the §6 datapath.
    ``delta`` rides the descent call for every strategy (DESIGN.md §7/§8).
    """
    B = queries.shape[0]
    d_ops = None if delta is None else delta_lib.operands(delta)
    if plan.strategy == "hrz":
        val, found = descend_phase(
            plan.forest_keys,
            plan.forest_values,
            plan.forest_height,
            queries[None, :],
            use_kernel=use_kernel,
            interpret=interpret,
            delta=d_ops,
        )
        return val[0], found[0]

    if plan.strategy == "dup":
        n = plan.n_trees
        pad = (-B) % n
        q = jnp.pad(queries, (0, pad)).reshape(n, -1)
        val, found = descend_phase(
            plan.forest_keys,
            plan.forest_values,
            plan.forest_height,
            q,
            shared_tree=True,
            use_kernel=use_kernel,
            interpret=interpret,
            delta=d_ops,
        )
        return val.reshape(-1)[:B], found.reshape(-1)[:B]

    # hyb: route + dispatch + descent + stall replay + delta merge, all
    # inside the one forest call's 2-output configuration (DESIGN.md §8).
    val, found = _hybrid_descend(
        plan,
        queries,
        ordered=False,
        use_kernel=use_kernel,
        interpret=interpret,
        delta=d_ops,
    )
    return val, found


def ordered_query(
    plan: SearchPlan,
    op: str,
    queries: jax.Array,
    queries_hi: Optional[jax.Array] = None,
    *,
    k: int = 8,
    use_kernel: bool = False,
    interpret: bool = True,
    delta: Optional[delta_lib.DeltaBuffer] = None,
):
    """The per-op query contract (DESIGN.md §6) -- one descent, one epilogue.

    * ``lookup(q)``           -> (values, found)
    * ``predecessor(q)``      -> (keys, values, ok): largest stored key <= q
    * ``successor(q)``        -> (keys, values, ok): smallest stored key >= q
    * ``range_count(lo, hi)`` -> counts of stored keys in [lo, hi]
    * ``range_scan(lo, hi)``  -> (keys (B, k), values (B, k), counts): the
      first ``k`` in-order pairs of [lo, hi], sentinel-padded past the end;
      ``counts`` is clipped to ``k`` (the bounded-scan contract).

    Range ops descend the concatenated ``lo || hi`` batch, so every op costs
    exactly one forest ``pallas_call``; the epilogues are rank arithmetic
    plus (for range_scan) a gather through the rank -> BFS map.  Keys and
    bounds must be strictly inside (NO_PRED_KEY, SENTINEL_KEY); when ``ok``
    is False the key output is NO_PRED_KEY / NO_SUCC_KEY and the value
    SENTINEL_VALUE.

    With ``delta`` (the live write path, DESIGN.md §7) the same descent
    resolves the pending upserts/tombstones, and every epilogue switches to
    its delta-aware twin in ``core/delta.py`` -- rank selection over the
    merged key set instead of the static rank -> BFS map.  An empty buffer
    degenerates to the classic answers bit-for-bit, so one compiled
    function serves the engine before and after writes land.
    """
    validate_op(op, queries_hi is not None)

    if op == "lookup":
        # The hot membership path: same phases, 2-output kernel config.
        return execute_plan(
            plan, queries, use_kernel=use_kernel, interpret=interpret, delta=delta
        )

    if op in RANGE_OPS:
        lo, hi = queries, queries_hi
        B = lo.shape[0]
        res = execute_plan_ordered(
            plan,
            jnp.concatenate([lo, hi]),
            use_kernel=use_kernel,
            interpret=interpret,
            delta=delta,
        )
        r_lo = OrderedResult(*(f[:B] for f in res))
        r_hi = OrderedResult(*(f[B:] for f in res))
        if delta is not None:
            sorted_keys, sorted_values = plan.sorted_view()
            return delta_lib.range_epilogue(
                op,
                sorted_keys,
                sorted_values,
                plan.full_tree.n_real,
                delta,
                r_lo,
                r_hi,
                k=k,
            )
        return range_epilogue(
            op, plan.full_tree, plan.rank_to_bfs, r_lo, r_hi, k=k
        )

    res = execute_plan_ordered(
        plan, queries, use_kernel=use_kernel, interpret=interpret, delta=delta
    )
    if delta is not None:
        sorted_keys, sorted_values = plan.sorted_view()
        return delta_lib.point_epilogue(
            op,
            queries,
            res,
            sorted_keys,
            sorted_values,
            plan.full_tree.n_real,
            delta,
        )
    return point_epilogue(op, queries, res)


def point_epilogue(op: str, queries: jax.Array, res: OrderedResult):
    """Per-lane epilogue of the single-batch ops (shared with distributed)."""
    if op == "lookup":
        return res.value, res.found
    if op == "predecessor":
        # floor(q): q itself on an exact hit, else the strict predecessor.
        keys = jnp.where(res.found, queries, res.pred_key)
        values = jnp.where(res.found, res.value, res.pred_value)
        ok = res.found | (res.pred_key != tree_lib.NO_PRED_KEY)
        return keys, values, ok
    # successor: ceiling(q).
    keys = jnp.where(res.found, queries, res.succ_key)
    values = jnp.where(res.found, res.value, res.succ_value)
    ok = res.found | (res.succ_key != tree_lib.NO_SUCC_KEY)
    return keys, values, ok


def range_epilogue(
    op: str,
    full_tree: TreeData,
    rank_to_bfs: jax.Array,
    r_lo: OrderedResult,
    r_hi: OrderedResult,
    *,
    k: int = 8,
):
    """Rank arithmetic over the sorted view (shared with distributed).

    |[lo, hi]| = rank_le(hi) - rank_lt(lo); empty ranges (lo > hi) clamp to
    0.  range_scan gathers the first ``k`` ranks through the rank -> BFS
    map, so the "sorted view" is read straight out of the flat layout.
    """
    counts = jnp.maximum(r_hi.rank + r_hi.found.astype(jnp.int32) - r_lo.rank, 0)
    if op == "range_count":
        return counts
    take = jnp.minimum(counts, k)
    ranks = r_lo.rank[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < take[:, None]
    bfs = rank_to_bfs[jnp.clip(ranks, 0, full_tree.n_nodes - 1)]
    keys = jnp.where(valid, full_tree.keys[bfs], tree_lib.SENTINEL_KEY)
    values = jnp.where(valid, full_tree.values[bfs], tree_lib.SENTINEL_VALUE)
    return keys, values, take
