"""Bulk Insert / Delete -- the paper's announced extension, TPU-native.

The paper closes with: "We are working on the extension of this work to
cover the BST construction phase by adding Delete and Insert operations."
A pointer-chasing incremental BST insert is hostile to both FPGAs (the
original authors deferred it) and TPUs (serial, data-dependent writes).
The TPU-native rendition is BULK maintenance, the standard LSM-ish trade:

  * ``bulk_insert``: merge a sorted batch of new pairs into the sorted
    key/value view (vectorized two-pointer merge via searchsorted rank
    arithmetic) and re-layout Eytzinger.  O(n + m) fully-vectorized work,
    zero host loops -- compare the O(m log n) *serial* pointer inserts a
    CPU would do.
  * ``bulk_delete``: mask + compact + re-layout.

Since the delta write path landed (DESIGN.md §7) both are thin wrappers
over ``core/delta.py``: the batch is ingested into a transient
batch-sized delta buffer (one ordered descent classifies each key) and
immediately compacted -- searchsorted merge plus Eytzinger re-layout, all
pure jnp under ``jit``, with a single host sync for the new key count
(it fixes the fresh snapshot's static height).  The host-side NumPy merge
this module used to carry is gone; only input validation runs on host.
Compile-cost caveat: the jitted programs specialize on (tree size, batch
size), which change across snapshot swaps, so a long stream of bulk calls
retraces per shape -- this is the COLD maintenance path by design; a
continuous write stream belongs on ``BSTEngine.apply_updates``, whose
fixed-shape delta ingest compiles once (DESIGN.md §7).

Both return a fresh TreeData; the engine strategies (and the forest-batched
flat Pallas kernel) consume the result unchanged, because every layout
invariant -- including the sorted in-order view that the ordered query ops'
rank arithmetic reads (DESIGN.md §6) -- is re-established by construction
(asserted by the compaction-invariant tests in ``tests/test_updates.py``).
Throughput-wise this remains the snapshot-swap deployment story; the
continuous-write story is ``BSTEngine.apply_updates`` (DESIGN.md §7).

Duplicate-key policy: an inserted key that already exists REPLACES the
stored value (upsert), matching map semantics used by the lookup tests.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delta as delta_lib
from repro.core import tree as tree_lib
from repro.core.tree import TreeData


def sorted_view(tree: TreeData) -> Tuple[np.ndarray, np.ndarray]:
    """Recover the sorted key/value arrays from the BFS layout (host)."""
    keys = np.asarray(tree.keys)
    values = np.asarray(tree.values)
    real = keys != tree_lib.SENTINEL_KEY
    order = np.argsort(keys[real], kind="stable")
    return keys[real][order], values[real][order]


@functools.partial(jax.jit, static_argnames=("height", "n_real"))
def _ingest_batch(
    tree_keys: jax.Array,
    tree_values: jax.Array,
    height: int,
    n_real: int,
    keys: jax.Array,
    values: jax.Array,
    deletes: jax.Array,
) -> delta_lib.DeltaBuffer:
    """Classify one write batch against the snapshot and buffer it.

    One ordered descent yields each key's membership + rank (the delta
    entry metadata, DESIGN.md §7); ``ingest`` then sorts and dedups the
    batch last-wins.  Fully on device.
    """
    tree = TreeData(tree_keys, tree_values, height, n_real)
    res = tree_lib.search_reference_ordered(tree, keys)
    return delta_lib.ingest(
        delta_lib.empty(keys.shape[0]),
        keys,
        values,
        deletes,
        jnp.ones(keys.shape, bool),
        res.found,
        res.rank,
    )


def _apply_batch(tree: TreeData, keys, values, deletes) -> TreeData:
    d = _ingest_batch(
        tree.keys,
        tree.values,
        tree.height,
        tree.n_real,
        jnp.asarray(keys, jnp.int32),
        jnp.asarray(values, jnp.int32),
        jnp.asarray(deletes, bool),
    )
    return delta_lib.compact(tree, d)


def bulk_insert(tree: TreeData, new_keys, new_values) -> TreeData:
    """Upsert a batch of pairs; returns a freshly laid-out perfect tree."""
    new_keys = np.asarray(new_keys, dtype=np.int32)
    new_values = np.asarray(new_values, dtype=np.int32)
    if new_keys.ndim != 1 or new_keys.shape != new_values.shape:
        raise ValueError("new_keys/new_values must be equal-length 1-D")
    if new_keys.size == 0:
        return tree
    return _apply_batch(tree, new_keys, new_values, np.zeros(new_keys.size, bool))


def bulk_delete(tree: TreeData, del_keys) -> TreeData:
    """Remove a batch of keys (absent keys are ignored; scalars accepted)."""
    del_keys = np.atleast_1d(np.asarray(del_keys, dtype=np.int32))
    if del_keys.ndim != 1:
        raise ValueError("del_keys must be scalar or 1-D")
    if del_keys.size == 0:
        return tree
    try:
        return _apply_batch(
            tree,
            del_keys,
            np.zeros(del_keys.size, np.int32),
            np.ones(del_keys.size, bool),
        )
    except ValueError as e:
        if "empty the tree" in str(e):
            raise ValueError("bulk_delete would empty the tree") from None
        raise
