"""Bulk Insert / Delete -- the paper's announced extension, TPU-native.

The paper closes with: "We are working on the extension of this work to
cover the BST construction phase by adding Delete and Insert operations."
A pointer-chasing incremental BST insert is hostile to both FPGAs (the
original authors deferred it) and TPUs (serial, data-dependent writes).
The TPU-native rendition is BULK maintenance, the standard LSM-ish trade:

  * ``bulk_insert``: merge a sorted batch of new pairs into the sorted
    key/value view (vectorized two-pointer merge via searchsorted rank
    arithmetic) and re-layout Eytzinger.  O(n + m) fully-vectorized work,
    zero host loops -- compare the O(m log n) *serial* pointer inserts a
    CPU would do.
  * ``bulk_delete``: mask + compact + re-layout.

Both return a fresh TreeData; the engine strategies (and the forest-batched
flat Pallas kernel) consume the result unchanged, because every layout
invariant -- including the sorted in-order view that the ordered query ops'
rank arithmetic reads (DESIGN.md §6) -- is re-established by construction.
Throughput-wise this matches the paper's deployment story: search streams
are served from immutable snapshots; updates land in batches between
snapshot swaps.

Duplicate-key policy: an inserted key that already exists REPLACES the
stored value (upsert), matching map semantics used by the lookup tests.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_lib
from repro.core.tree import TreeData


def sorted_view(tree: TreeData) -> Tuple[np.ndarray, np.ndarray]:
    """Recover the sorted key/value arrays from the BFS layout (host)."""
    keys = np.asarray(tree.keys)
    values = np.asarray(tree.values)
    real = keys != tree_lib.SENTINEL_KEY
    order = np.argsort(keys[real], kind="stable")
    return keys[real][order], values[real][order]


def bulk_insert(tree: TreeData, new_keys, new_values) -> TreeData:
    """Upsert a batch of pairs; returns a freshly laid-out perfect tree."""
    new_keys = np.asarray(new_keys, dtype=np.int32)
    new_values = np.asarray(new_values, dtype=np.int32)
    if new_keys.ndim != 1 or new_keys.shape != new_values.shape:
        raise ValueError("new_keys/new_values must be equal-length 1-D")
    order = np.argsort(new_keys, kind="stable")
    nk, nv = new_keys[order], new_values[order]
    # last occurrence wins within the batch (upsert semantics)
    keep = np.ones(nk.size, bool)
    keep[:-1] = nk[:-1] != nk[1:]
    nk, nv = nk[keep], nv[keep]

    ok, ov = sorted_view(tree)
    # drop old pairs that are being replaced
    replaced = np.isin(ok, nk, assume_unique=True)
    ok, ov = ok[~replaced], ov[~replaced]

    # vectorized merge by rank arithmetic: position of each element in the
    # merged array = own index + count of smaller elements in the other set
    pos_old = np.arange(ok.size) + np.searchsorted(nk, ok, side="left")
    pos_new = np.arange(nk.size) + np.searchsorted(ok, nk, side="left")
    total = ok.size + nk.size
    mk = np.empty(total, np.int32)
    mv = np.empty(total, np.int32)
    mk[pos_old], mv[pos_old] = ok, ov
    mk[pos_new], mv[pos_new] = nk, nv

    bfs_k, bfs_v, h, n_real = tree_lib.eytzinger_from_sorted(mk, mv)
    return TreeData(jnp.asarray(bfs_k), jnp.asarray(bfs_v), h, n_real)


def bulk_delete(tree: TreeData, del_keys) -> TreeData:
    """Remove a batch of keys (absent keys are ignored)."""
    del_keys = np.unique(np.asarray(del_keys, dtype=np.int32))
    ok, ov = sorted_view(tree)
    keep = ~np.isin(ok, del_keys, assume_unique=True)
    ok, ov = ok[keep], ov[keep]
    if ok.size == 0:
        raise ValueError("bulk_delete would empty the tree")
    bfs_k, bfs_v, h, n_real = tree_lib.eytzinger_from_sorted(ok, ov)
    return TreeData(jnp.asarray(bfs_k), jnp.asarray(bfs_v), h, n_real)
