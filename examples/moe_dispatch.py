"""The paper's buffers inside a Mixtral MoE layer: direct vs queue mapping.

    PYTHONPATH=src python examples/moe_dispatch.py

Shows the FPGA insight carried into the LM substrate: expert dispatch with
capacity is exactly the paper's buffer placement problem.  The queue mapping
(prefix-sum compaction) keeps strictly more token->expert assignments than
the direct (position-slot) mapping at every capacity factor -- the Fig.5 vs
Fig.6 behaviour -- which directly translates into model quality under load.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import model as M
from repro.models.moe import expert_capacity, moe_ffn


def main():
    cfg = smoke_config("mixtral_8x7b")
    params = M.init_params(cfg, jax.random.key(0))
    # one layer's worth of MoE params
    lp = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.key(1), (8, 64, cfg.d_model)) * 0.5
    T = x.shape[0] * x.shape[1]

    print(f"tokens={T} experts={cfg.n_experts} top_k={cfg.top_k}")
    print(f"{'capacity_factor':>16s} {'capacity':>9s} {'queue drop%':>12s} {'direct drop%':>13s}")
    for cf in (0.5, 0.75, 1.0, 1.25, 1.5, 2.0):
        drops = {}
        for mapping in ("queue", "direct"):
            c = dataclasses.replace(cfg, capacity_factor=cf, moe_dispatch=mapping)
            _, dropped = moe_ffn(c, lp, x)
            drops[mapping] = float(dropped) * 100
        cap = expert_capacity(dataclasses.replace(cfg, capacity_factor=cf), T)
        print(
            f"{cf:16.2f} {cap:9d} {drops['queue']:12.2f} {drops['direct']:13.2f}"
        )
    print("\nqueue mapping == the paper's contribution, and is the default for")
    print("the mixtral-8x7b / mixtral-8x22b configs (moe_dispatch='queue').")


if __name__ == "__main__":
    main()
