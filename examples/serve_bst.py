"""End-to-end driver: serve a BST key-value store with batched requests.

    PYTHONPATH=src python examples/serve_bst.py [--requests 200000]

This is the paper-kind end-to-end scenario (a throughput accelerator): a
request stream is submitted to ``serving.BSTServer``, which packs it into
fixed-shape chunks, dispatches them through the engine configured with each
of the paper's strategies, and accounts achieved keys/second (found counts
accumulated per chunk).  An ordered-workload mix (predecessor / range_count
/ range_scan request kinds, DESIGN.md §6) exercises the typed-request
scheduler with per-op accounting.  A LIVE mixed read/write stream
(``--write-rate``) then runs through the delta write path (DESIGN.md §7):
upserts and deletes land in the engine's device-side buffer via
``submit_write`` / ``submit_delete`` in submission order, and compaction
merges them into fresh snapshots at the high-water mark -- no full
rebuilds.  A bulk insert/delete then swaps in a fresh immutable snapshot
the legacy way.  The distributed section demonstrates the multi-chip
hybrid engine: the tree vertically partitioned over a (data, model) mesh,
keys routed by the queue-mapped all_to_all (8 simulated devices), serving
the same ``query(op, ...)`` contract.  The final section scales the SERVER
itself out (DESIGN.md §9): ``BSTServer(mesh=...)`` routes every chunk
through the strategy's shard_map-lowered plan behind the async
double-buffered scheduler, live writes included -- the pending delta
buffer rides each sharded read as replicated operands and compactions
rebuild the sharded programs mid-service.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import PAPER_CONFIGS, build_tree
from repro.core.distributed import (
    make_distributed_query,
    make_dup_query,
    make_serving_mesh,
)
from repro.data.keysets import make_tree_data
from repro.serving import BSTServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200_000)
    ap.add_argument("--chunk", type=int, default=8_192)
    ap.add_argument("--tree-keys", type=int, default=(1 << 16) - 1)
    ap.add_argument(
        "--write-rate",
        type=float,
        default=0.1,
        help="fraction of the live mixed stream that is writes (DESIGN.md §7)",
    )
    args = ap.parse_args()

    keys, values = make_tree_data(args.tree_keys, seed=0)
    rng = np.random.default_rng(1)
    stream = rng.choice(keys, args.requests).astype(np.int32)

    print(f"serving {args.requests} lookups in chunks of {args.chunk}")
    print(f"{'impl':8s} {'keys/s':>12s} {'found':>10s} {'memory(nodes)':>14s}")
    for name, cfg in PAPER_CONFIGS.items():
        srv = BSTServer(keys, values, cfg, chunk_size=args.chunk)
        srv.warmup()
        srv.submit(stream)
        srv.drain()
        s = srv.stats
        print(
            f"{name:8s} {s.keys_per_sec:12.0f} {s.found:10d} "
            f"{srv.memory_nodes():14d}"
        )

    # ---- ordered workload mix: typed request kinds, per-op accounting
    srv = BSTServer(keys, values, PAPER_CONFIGS["Hyb8q"], chunk_size=args.chunk)
    srv.warmup(("predecessor", "range_count", "range_scan"))
    n_ord = max(args.chunk, args.requests // 8)
    ord_keys = rng.choice(np.concatenate([keys, keys + 1]), n_ord).astype(np.int32)
    lo = rng.choice(keys, n_ord).astype(np.int32)
    hi = (lo + rng.integers(0, 64, n_ord)).astype(np.int32)
    srv.submit(ord_keys, op="predecessor")
    srv.submit_range(lo, hi, op="range_count")
    srv.submit_range(lo, hi, op="range_scan")
    srv.drain()
    print("\nordered workload mix (Hyb8q):")
    print(f"{'op':12s} {'served':>10s} {'chunks':>7s} {'keys/s':>12s}")
    for op, st in srv.stats.per_op.items():
        print(f"{op:12s} {st.served:10d} {st.chunks:7d} {st.keys_per_sec:12.0f}")

    # ---- live write path: delta-buffered updates, compaction, no rebuilds
    cfg = dataclasses.replace(PAPER_CONFIGS["Hyb8q"], delta_capacity=4096)
    srv = BSTServer(keys, values, cfg, chunk_size=args.chunk)
    srv.warmup()
    n_live = max(args.chunk, args.requests // 4)
    n_w = int(n_live * args.write_rate)
    wk = rng.integers(1, 2**20, n_w).astype(np.int32)
    reads = rng.choice(np.concatenate([keys, wk]), n_live - n_w).astype(np.int32)
    t0 = time.perf_counter()
    half = n_w // 2
    srv.submit_write(wk[:half], wk[:half] * 3)  # upserts ...
    srv.submit(reads[: reads.size // 2])  # ... reads see them after the barrier
    srv.submit_delete(wk[:half:7])  # tombstones ride the same queue
    srv.submit_write(wk[half:], wk[half:] * 3)
    srv.submit(reads[reads.size // 2 :])
    srv.drain()
    dt = time.perf_counter() - t0
    s = srv.stats
    print(
        f"\nlive write path (Hyb8q, {args.write_rate:.0%} writes): "
        f"{s.served / dt:.0f} keys/s end-to-end, {s.updates} updates absorbed "
        f"on device, {s.compactions} compaction(s), 0 rebuilds"
    )
    v, f = srv.lookup(wk[half + 1 : half + 9])
    print(f"  post-write lookups: found {int(np.asarray(f).sum())}/8 fresh keys")

    # ---- snapshot swap: bulk updates land between chunk streams
    srv = BSTServer(keys, values, PAPER_CONFIGS["Hyb8q"], chunk_size=args.chunk)
    new_keys = np.arange(1, 2_001, 2, dtype=np.int32)  # odd keys: all absent
    srv.apply_updates(
        insert_keys=new_keys,
        insert_values=new_keys * 10,
        delete_keys=keys[:1000],
    )
    v, f = srv.lookup(new_keys)
    dead_v, dead_f = srv.lookup(keys[:1000])
    print(
        f"\nsnapshot swap: inserted {new_keys.size} (found {int(f.sum())}), "
        f"deleted 1000 (still found {int(dead_f.sum())}), "
        f"{srv.stats.snapshot_swaps} swap(s)"
    )

    # ---- multi-chip: vertical partitioning over the model axis
    print("\ndistributed hybrid engine (8 devices, 2x4 data x model mesh):")
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
    tree = build_tree(keys, values)
    chunks = [
        stream[i : i + args.chunk] for i in range(0, len(stream), args.chunk)
    ][:8]
    if len(chunks[-1]) != args.chunk:  # pad the final partial chunk (jit shape)
        chunks[-1] = np.pad(chunks[-1], (0, args.chunk - len(chunks[-1])))
    with mesh:
        for label, maker in (
            ("vertical(all_to_all)", lambda: make_distributed_query(tree, mesh, "model")),
            ("duplicated(DP)", lambda: make_dup_query(tree, mesh, "data")),
        ):
            query = maker()
            jax.block_until_ready(query("lookup", chunks[0]))
            t0 = time.perf_counter()
            for c in chunks:
                v, f = query("lookup", c)
            jax.block_until_ready(v)
            dt = time.perf_counter() - t0
            print(f"  {label:22s} {len(chunks) * args.chunk / dt:12.0f} keys/s")
            # the same handle serves ordered ops (predecessor shown)
            pk, pv, ok = query("predecessor", chunks[0])
            print(f"  {'':22s} predecessor ok for {int(np.asarray(ok).sum())} keys")

    # ---- sharded serving: the server itself over the mesh (DESIGN.md §9)
    print("\nsharded BSTServer (8 devices, double-buffered scheduler):")
    print(f"{'strategy':10s} {'keys/s':>12s} {'chunks':>7s} {'found':>10s}")
    n_srv = max(args.chunk * 4, args.requests // 4)
    srv_stream = rng.choice(keys, n_srv).astype(np.int32)
    for strategy, n_trees in (("hrz", 1), ("dup", 8), ("hyb", 8)):
        cfg = dataclasses.replace(
            PAPER_CONFIGS["Hyb8q" if strategy == "hyb" else "Hrz"],
            strategy=strategy,
            n_trees=n_trees,
        )
        srv = BSTServer(
            keys, values, cfg, chunk_size=args.chunk,
            mesh=make_serving_mesh(strategy),
        )
        srv.warmup()
        srv.submit(srv_stream)
        srv.drain()
        s = srv.stats
        print(f"{strategy:10s} {s.keys_per_sec:12.0f} {s.chunks:7d} {s.found:10d}")

    # live writes through the sharded hybrid server: the delta buffer rides
    # every sharded read as replicated operands, folded on-device
    cfg = dataclasses.replace(
        PAPER_CONFIGS["Hyb8q"], delta_capacity=4096
    )
    srv = BSTServer(
        keys, values, cfg, chunk_size=args.chunk, mesh=make_serving_mesh("hyb")
    )
    srv.warmup()
    wk = rng.integers(1, 2**20, args.chunk).astype(np.int32)
    srv.submit_write(wk, wk * 5)
    srv.submit(wk[: args.chunk // 2])
    srv.drain()
    v, f = srv.lookup(wk[:8])
    print(
        f"  sharded write path: {srv.stats.updates} updates absorbed, "
        f"{int(np.asarray(f).sum())}/8 fresh keys found, "
        f"{srv.stats.compactions} compaction(s)"
    )


if __name__ == "__main__":
    main()
