"""End-to-end driver: serve a BST key-value store with batched requests.

    PYTHONPATH=src python examples/serve_bst.py [--requests 200000]

This is the paper-kind end-to-end scenario (a throughput accelerator):
a request stream is chunked, dispatched through the engine configured with
each of the paper's strategies, and the achieved keys/second is reported.
The distributed section demonstrates the multi-chip hybrid engine: the tree
vertically partitioned over a (data, model) mesh, keys routed by the
queue-mapped all_to_all (8 simulated devices).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import numpy as np

from repro.core import BSTEngine, PAPER_CONFIGS, build_tree
from repro.core.distributed import make_distributed_lookup, make_dup_lookup
from repro.data.keysets import make_tree_data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200_000)
    ap.add_argument("--chunk", type=int, default=8_192)
    ap.add_argument("--tree-keys", type=int, default=(1 << 16) - 1)
    args = ap.parse_args()

    keys, values = make_tree_data(args.tree_keys, seed=0)
    rng = np.random.default_rng(1)
    stream = rng.choice(keys, args.requests).astype(np.int32)
    chunks = [
        stream[i : i + args.chunk] for i in range(0, len(stream), args.chunk)
    ]
    if len(chunks[-1]) != args.chunk:
        chunks[-1] = np.pad(chunks[-1], (0, args.chunk - len(chunks[-1])))

    print(f"serving {args.requests} lookups in {len(chunks)} chunks of {args.chunk}")
    print(f"{'impl':8s} {'keys/s':>12s} {'found':>10s} {'memory(nodes)':>14s}")
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, cfg)
        eng.lookup(chunks[0])  # warm the jit cache
        found = 0
        t0 = time.perf_counter()
        for c in chunks:
            v, f = eng.lookup(c)
        jax.block_until_ready(v)
        dt = time.perf_counter() - t0
        found = int(np.asarray(f).sum())
        print(
            f"{name:8s} {args.requests / dt:12.0f} {found:10d} "
            f"{eng.memory_nodes():14d}"
        )

    # ---- multi-chip: vertical partitioning over the model axis
    print("\ndistributed hybrid engine (8 devices, 2x4 data x model mesh):")
    mesh = jax.make_mesh(
        (2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
    tree = build_tree(keys, values)
    with mesh:
        for label, maker in (
            ("vertical(all_to_all)", lambda: make_distributed_lookup(tree, mesh, "model")),
            ("duplicated(DP)", lambda: make_dup_lookup(tree, mesh, "data")),
        ):
            look = maker()
            look(chunks[0])
            t0 = time.perf_counter()
            for c in chunks[:8]:
                v, f = look(c)
            jax.block_until_ready(v)
            dt = time.perf_counter() - t0
            print(f"  {label:22s} {8 * args.chunk / dt:12.0f} keys/s")


if __name__ == "__main__":
    main()
