"""Quickstart: the BST accelerator's public API in 80 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a key/value tree, runs lookups AND ordered queries (predecessor /
successor / range_count / range_scan, DESIGN.md §6) through every strategy
of the paper (horizontal / duplicated / hybrid direct / hybrid queue), and
reproduces the cycle-accurate throughput comparison on the paper's three
key distributions.
"""

import numpy as np

from repro.core import BSTEngine, EngineConfig, PAPER_CONFIGS, build_tree
from repro.core.cyclesim import run_paper_matrix
from repro.data.keysets import make_key_sets, make_tree_data


def main():
    # 1) one million-ish keys -> perfect BFS-layout tree
    keys, values = make_tree_data((1 << 14) - 1, seed=0)
    engine = BSTEngine(keys, values, EngineConfig(strategy="hyb", n_trees=8))
    print(f"tree: {engine.tree.n_nodes} nodes, height {engine.tree.height}")

    # 2) batched lookup (hybrid partitioning + queue-mapped buffers)
    rng = np.random.default_rng(1)
    queries = rng.choice(np.concatenate([keys, keys + 1]), 4096).astype(np.int32)
    vals, found = engine.lookup(queries)
    print(f"looked up {queries.size} keys: {int(found.sum())} found")

    # 3) ordered queries ride the same single descent (DESIGN.md §6)
    pk, pv, ok = engine.query("predecessor", queries)  # floor(q)
    sk, sv, sok = engine.query("successor", queries)  # ceiling(q)
    lo, hi = queries, (queries + 64).astype(np.int32)
    counts = engine.query("range_count", lo, hi)  # |[lo, hi]|
    rk, rv, taken = engine.query("range_scan", lo, hi, k=4)  # first 4 pairs
    print(
        f"ordered: {int(ok.sum())} predecessors, {int(sok.sum())} successors, "
        f"mean range size {float(counts.mean()):.1f}, "
        f"scanned {int(taken.sum())} pairs"
    )

    # 4) every strategy returns identical results -- only throughput differs
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, cfg)
        v, f = eng.lookup(queries)
        assert np.array_equal(np.asarray(v), np.asarray(vals))
        c = eng.query("range_count", lo, hi)
        assert np.array_equal(np.asarray(c), np.asarray(counts))
        print(f"  {name:6s}: identical results, memory={eng.memory_nodes()} nodes")

    # 5) the paper's evaluation: cycles to drain a key stream (Fig. 7)
    tree = build_tree(keys, values)
    sets = make_key_sets(tree, 16384)
    res = run_paper_matrix(tree, sets)
    print("\nspeedup vs Hrz (cycle-accurate):")
    impls = list(PAPER_CONFIGS)
    print("         " + "".join(f"{i:>8s}" for i in impls))
    for sname, row in res.items():
        base = row["Hrz"]
        print(
            f"{sname:>8s} "
            + "".join(f"{r.speedup_vs(base):8.2f}" for r in row.values())
        )


if __name__ == "__main__":
    main()
