"""Train a small LM end-to-end with the full framework stack.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --steps 200

Uses the reduced (smoke) architecture config on CPU: real data pipeline,
AdamW + cosine schedule, grad clipping, checkpointing every 50 steps, and
restart-resume -- the same code path the launcher runs at scale.
"""

import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    print(f"checkpoints -> {ckpt}")
    train_main(
        [
            "--arch", args.arch,
            "--steps", str(args.steps),
            "--batch", str(args.batch),
            "--seq", str(args.seq),
            "--smoke",
            "--ckpt-dir", ckpt,
            "--ckpt-every", "50",
            "--lr", "3e-3",
        ]
    )


if __name__ == "__main__":
    main()
