"""MoE dispatch drop-rates: the paper's direct-vs-queue trade-off inside the
Mixtral FFN (the Fig.5/Fig.6 behaviour surfaced at the model level).

Sweeps capacity_factor and reports the dropped-assignment fraction per
mapping; queue must dominate direct at every capacity (tests assert it)."""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.configs import smoke_config
from repro.core import buffers as B


def run() -> List[Row]:
    cfg = smoke_config("mixtral_8x7b")
    rng = np.random.default_rng(0)
    rows: List[Row] = []
    T_, E, K = 4096, 8, 2
    # router-like skewed expert choice (zipf-ish), the realistic stress case
    probs = np.array([2.0 ** (-i) for i in range(E)])
    probs /= probs.sum()
    for skew, name in ((None, "uniform"), (probs, "skewed")):
        dest = rng.choice(E, size=T_ * K, p=skew).astype(np.int32)
        for cf in (0.5, 1.0, 1.25, 2.0):
            cap = max(1, int(T_ * K / E * cf))
            for mapping in ("queue", "direct"):
                plan = B.dispatch(mapping, jnp.asarray(dest), E, cap)
                dropped = 1 - float(plan.kept.sum()) / (T_ * K)
                rows.append(
                    Row(
                        name=f"moe_dispatch/{name}/cf{cf}/{mapping}",
                        us_per_call=0.0,
                        derived=f"dropped_frac={dropped:.4f};capacity={cap}",
                    )
                )
    return rows
