# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entrypoint.

  fig7  -- acceleration vs Hrz (paper Fig. 7): cycle-accurate reproduction
  fig8  -- memory/utilization vs Hrz (paper Fig. 8)
  fig9  -- timing/energy proxies (paper Fig. 9, modeled; see module doc)
  engine-- real JAX engine throughput (keys/s) for all strategies x query ops
  kernel-- Pallas kernels (interpret) vs jnp oracles
  moe   -- MoE dispatch drop rates: direct vs queue mapping
  roofline -- dry-run-derived three-term roofline per (arch x shape)

Run all: ``PYTHONPATH=src python -m benchmarks.run``
Subset : ``PYTHONPATH=src python -m benchmarks.run --only fig7,engine``
Quick  : ``PYTHONPATH=src python -m benchmarks.run --quick``
JSON   : add ``--json BENCH_4.json`` to also dump the rows as a schema-
         checked machine-readable artifact (what CI uploads per run;
         scripts/check_bench.py layers the hyb kernel-vs-driver
         regression gate on top of the same file).
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

# The machine-readable artifact contract (BENCH_*.json).  scripts/
# check_bench.py re-validates the same schema on the consumer side and
# layers the hyb kernel-vs-driver regression gate on top.
SCHEMA = "bench-rows/v1"


def validate_rows(records) -> None:
    """Schema-check the JSON rows before they are written anywhere.

    Every record is exactly ``{suite, name, us_per_call, derived}`` with a
    non-negative timing and a ``key=value`` ``;``-separated derived payload
    -- the shape every downstream consumer (CI gates, dashboards) parses.
    """
    if not isinstance(records, list) or not records:
        raise SystemExit("bench JSON: no rows to write")
    for r in records:
        if set(r) != {"suite", "name", "us_per_call", "derived"}:
            raise SystemExit(f"bench JSON: bad record keys {sorted(r)}")
        if not (isinstance(r["suite"], str) and r["suite"]):
            raise SystemExit(f"bench JSON: bad suite in {r}")
        if not (isinstance(r["name"], str) and r["name"]):
            raise SystemExit(f"bench JSON: bad name in {r}")
        if not isinstance(r["us_per_call"], (int, float)) or r["us_per_call"] < 0:
            raise SystemExit(f"bench JSON: bad us_per_call in {r}")
        if not isinstance(r["derived"], str):
            raise SystemExit(f"bench JSON: bad derived in {r}")
        for part in filter(None, r["derived"].split(";")):
            if "=" not in part:
                raise SystemExit(
                    f"bench JSON: derived part {part!r} is not key=value ({r})"
                )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of suites")
    ap.add_argument("--quick", action="store_true", help="small sizes (CI)")
    ap.add_argument("--json", default=None, help="also write rows to this JSON file")
    args = ap.parse_args()

    from benchmarks import (
        engine_throughput,
        fig7_acceleration,
        fig8_memory,
        fig9_resources,
        kernel_bench,
        moe_dispatch_bench,
        roofline,
    )

    suites = {
        "fig7": (
            (lambda: fig7_acceleration.run(sizes=(16384,)))
            if args.quick
            else fig7_acceleration.run
        ),
        "fig8": fig8_memory.run,
        "fig9": fig9_resources.run,
        "engine": (
            (lambda: engine_throughput.run(n_keys=(1 << 12) - 1, batch=8192, quick=True))
            if args.quick
            else engine_throughput.run
        ),
        "kernel": kernel_bench.run,
        "moe": moe_dispatch_bench.run,
        "roofline": roofline.run,
    }
    only = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failures = 0
    records = []
    for name in only:
        try:
            for row in suites[name]():
                print(row.csv())
                records.append(
                    {
                        "suite": name,
                        "name": row.name,
                        "us_per_call": row.us_per_call,
                        "derived": row.derived,
                    }
                )
        except Exception as e:
            failures += 1
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.json:
        if records:
            validate_rows(records)
            with open(args.json, "w") as f:
                json.dump(
                    {"schema": SCHEMA, "quick": args.quick, "rows": records},
                    f,
                    indent=1,
                )
            print(f"wrote {len(records)} rows to {args.json}", file=sys.stderr)
        elif not failures:
            raise SystemExit("bench JSON: no rows produced")
        # with failures and zero rows, fall through: the suite-failure exit
        # below is the real error, and no stale/empty artifact is written
    if failures:
        raise SystemExit(f"{failures} suite(s) failed")


if __name__ == "__main__":
    main()
