"""Paper Fig. 9: timing (clock) and energy -- modeled proxies.

RTL clock frequency and nJ/key do not transfer to TPU (DESIGN.md §2); the
paper's published relationships are encoded as a calibrated model so the
benchmark harness still covers the figure:
  * direct mapping clocks 7-8 % faster than queue mapping (shorter critical
    path through the router);
  * hybrid implementations burn more energy than Hrz/Dup (extra routing
    logic), queue > direct.

On TPU, the analogous *measured* quantity is per-key work (vector-lane
occupancy), which we report from the real engine alongside the model.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.engine import PAPER_CONFIGS

# Calibrated to the paper's reported relationships (Fig. 9a/9b, §III).
MODEL_CLOCK_MHZ = {
    "Hrz": 250.0,
    "Dup4": 245.0,
    "Dup8": 240.0,
    "Hyb4": 230.0,
    "Hyb4q": 213.0,  # ~7.4% slower than direct (paper: 7-8%)
    "Hyb8": 225.0,
    "Hyb8q": 208.0,  # ~7.6% slower
}
MODEL_ENERGY_NJ_PER_KEY = {
    "Hrz": 1.0,
    "Dup4": 1.15,
    "Dup8": 1.3,
    "Hyb4": 1.5,
    "Hyb4q": 1.8,
    "Hyb8": 1.7,
    "Hyb8q": 2.1,
}


def run() -> List[Row]:
    rows = []
    for name in PAPER_CONFIGS:
        clock = MODEL_CLOCK_MHZ[name]
        direct_pair = name.rstrip("q")
        gap = ""
        if name.endswith("q"):
            gap = f";clock_vs_direct={clock / MODEL_CLOCK_MHZ[direct_pair] - 1:+.3f}"
        rows.append(
            Row(
                name=f"fig9/{name}",
                us_per_call=0.0,
                derived=(
                    f"model_clock_mhz={clock:.0f};"
                    f"model_energy_nj_per_key={MODEL_ENERGY_NJ_PER_KEY[name]:.2f}"
                    f"{gap}"
                ),
            )
        )
    return rows
