"""Roofline analysis per (arch x shape) from the dry-run artifacts.

Three terms, in seconds (TPU v5e constants):
  compute    = FLOPs / (chips * 197e12)          [analytic model, see
               analytic_model.py -- XLA cost_analysis undercounts scanned
               bodies; validated against unrolled HLO in tests]
  memory     = HBM bytes / (chips * 819e9)       [analytic lower bound]
  collective = wire bytes / (chips * 50e9)       [HLO-parsed, loop-trip
               multiplied, wire multipliers: AR 2x result, AG/RS/A2A/CP 1x]

Dominant term = the bottleneck; the §Perf loop iterates on it.
Reads experiments/dryrun/*.json, writes experiments/roofline.md.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.analytic_model import cell_cost
from benchmarks.common import Row
from repro.configs import ARCH_IDS, canonical, get_config
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (bandwidth-dominant direction)

WIRE_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

DRYRUN_DIR = os.environ.get(
    "ROOFLINE_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun"),
)


def load_cells(mesh: str = "single", directory: Optional[str] = None) -> Dict[str, dict]:
    out = {}
    for f in glob.glob(os.path.join(directory or DRYRUN_DIR, f"*_{mesh}.json")):
        rec = json.load(open(f))
        if "arch" not in rec:  # e.g. bst_engine_*.json (own roofline format)
            continue
        if rec.get("tag"):  # perf-variant artifacts live in §Perf, not here
            continue
        out[f"{canonical(rec['arch'])}|{rec['shape']}"] = rec
    return out


def wire_bytes(collectives: dict) -> float:
    total = 0.0
    for op, mult in WIRE_MULT.items():
        if op in collectives:
            total += collectives[op]["bytes"] * mult
    return total


def analyze_cell(rec: dict, chips: int = 256) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(canonical(rec["arch"]))
    shape = SHAPES[rec["shape"]]
    cost = cell_cost(cfg, shape)
    t_compute = cost.flops / (chips * PEAK_FLOPS)
    t_memory = cost.hbm_bytes / (chips * HBM_BW)
    # collective bytes in the JSON are per-device program bytes already
    wb = wire_bytes(rec.get("collectives", {}))
    t_coll = wb / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = t_compute / bound if bound > 0 else 0.0
    hlo_flops = rec.get("cost_analysis", {}).get("flops", 0.0)
    return {
        "arch": canonical(rec["arch"]),
        "shape": rec["shape"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,  # compute / dominant: 1.0 == compute-bound
        "model_flops": cost.model_flops,
        "analytic_flops": cost.flops,
        "useful_ratio": cost.model_flops / cost.flops if cost.flops else 0.0,
        "hlo_flops_raw_per_device": hlo_flops,
        "collective_wire_bytes_per_device": wb,
        "mem_peak_bytes_per_device": rec.get("memory_analysis", {}).get(
            "peak_per_device_bytes", 0
        ),
        "lever": _lever(dominant, cfg, shape),
    }


def _lever(dominant: str, cfg, shape) -> str:
    if dominant == "compute":
        return (
            "compute-bound: raise MFU via MXU-aligned tiles / fused kernels; "
            "remat policy trades the +1x forward recompute against HBM"
        )
        # noqa
    if dominant == "memory":
        if shape.kind == "decode":
            return (
                "KV/weight streaming bound: shrink cache reads (GQA already; "
                "quantize KV to int8, shard window over more chips)"
            )
        return "activation traffic: fuse norms/rope, wider microbatch, bf16 master"
    return (
        "collective-bound: reshard to cut all-gathers (seq-shard logits, "
        "overlap DP all-reduce with backward scan, compress grads)"
    )


def run() -> List[Row]:
    cells = load_cells("single")
    rows: List[Row] = []
    for key in sorted(cells):
        a = analyze_cell(cells[key])
        if a is None:
            continue
        rows.append(
            Row(
                name=f"roofline/{a['arch']}/{a['shape']}",
                us_per_call=a["t_compute_s"] * 1e6,
                derived=(
                    f"dominant={a['dominant']};"
                    f"t_compute={a['t_compute_s']:.4f}s;"
                    f"t_memory={a['t_memory_s']:.4f}s;"
                    f"t_collective={a['t_collective_s']:.4f}s;"
                    f"roofline_frac={a['roofline_fraction']:.3f};"
                    f"useful_ratio={a['useful_ratio']:.3f}"
                ),
            )
        )
    return rows


def write_markdown(
    path: str,
    mesh: str = "single",
    chips: int = 256,
    directory: Optional[str] = None,
    title: str = "",
) -> str:
    cells = load_cells(mesh, directory)
    lines = [
        f"### Roofline table {title}({mesh}-pod, {chips} chips, v5e: 197 TF/s bf16, "
        "819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | "
        "dominant | compute/dominant | 6ND/analytic | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    skipped = []
    for key in sorted(cells):
        rec = cells[key]
        if rec.get("status") == "skipped":
            skipped.append(f"- {rec['arch']} x {rec['shape']}: {rec['skip_reason']}")
            continue
        a = analyze_cell(rec, chips)
        if a is None:
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | - | - | - | ERROR | - | - | "
                f"{rec.get('error','')[:60]} |"
            )
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.4f} | "
            f"{a['t_memory_s']:.4f} | {a['t_collective_s']:.4f} | "
            f"**{a['dominant']}** | {a['roofline_fraction']:.2f} | "
            f"{a['useful_ratio']:.2f} | {a['lever']} |"
        )
    if skipped:
        lines += ["", "Skipped cells (documented in DESIGN.md §4):", *skipped]
    text = "\n".join(lines)
    with open(path, "w") as f:
        f.write(text + "\n")
    return text


if __name__ == "__main__":
    exp = os.path.join(os.path.dirname(__file__), "..", "experiments")
    print(write_markdown(os.path.join(exp, "roofline.md"), title="— paper-faithful baseline "))
    opt_dir = os.path.join(exp, "dryrun_opt")
    if os.path.isdir(opt_dir) and glob.glob(os.path.join(opt_dir, "*_single.json")):
        print(
            write_markdown(
                os.path.join(exp, "roofline_optimized.md"),
                directory=opt_dir,
                title="— optimized defaults ",
            )
        )
