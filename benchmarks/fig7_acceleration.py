"""Paper Fig. 7: acceleration rate vs Hrz for 64K and 256K key sets.

Reproduces the paper's central result with the cycle-accurate simulator:
  * Dup4 / Dup8: constant 4x / 8x regardless of key distribution
  * hybrids ~ 1x on Equal (port limit), ~ Nx on Split (conflict-free)
  * queue vs direct gap on Random (paper: 32-39%)
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import tree as T
from repro.core.cyclesim import run_paper_matrix
from repro.data.keysets import make_key_sets, make_tree_data

TREE_KEYS = (1 << 16) - 1  # 64K-node tree (paper: up to 2^20; CPU-box scale)


def run(sizes=(65536, 262144)) -> List[Row]:
    keys, values = make_tree_data(TREE_KEYS, seed=0)
    tree = T.build_tree(keys, values)
    rows: List[Row] = []
    for size in sizes:
        sets = make_key_sets(tree, size)
        t0 = time.perf_counter()
        res = run_paper_matrix(tree, sets)
        sim_us = (time.perf_counter() - t0) * 1e6
        for set_name, row in res.items():
            base = row["Hrz"]
            for impl, r in row.items():
                rows.append(
                    Row(
                        name=f"fig7/{size//1024}K/{set_name}/{impl}",
                        us_per_call=sim_us / len(res) / len(row),
                        derived=(
                            f"speedup_vs_hrz={r.speedup_vs(base):.3f};"
                            f"keys_per_cycle={r.keys_per_cycle:.3f};"
                            f"cycles={r.cycles};stalls={r.stall_cycles}"
                        ),
                    )
                )
        # paper-claim checks (reported, asserted in tests/test_cyclesim.py)
        rnd = res["random"]
        for n in (4, 8):
            d, q = rnd[f"Hyb{n}"], rnd[f"Hyb{n}q"]
            rows.append(
                Row(
                    name=f"fig7/{size//1024}K/claim/queue_vs_direct_Hyb{n}",
                    us_per_call=0.0,
                    derived=(
                        # two gap definitions: queue-speedup-over-direct, and
                        # the gap as a fraction of the queue acceleration
                        f"cycle_gain={d.cycles / q.cycles - 1:.3f};"
                        f"accel_gap_frac_of_queue={1 - q.cycles / d.cycles:.3f};"
                        f"paper_band=0.32-0.39"
                    ),
                )
            )
        rows.append(
            Row(
                name=f"fig7/{size//1024}K/claim/max_speedup",
                us_per_call=0.0,
                derived=(
                    f"dup8_speedup={res['random']['Dup8'].speedup_vs(res['random']['Hrz']):.2f};"
                    f"dup8_keys_per_cycle={res['random']['Dup8'].keys_per_cycle:.2f};"
                    f"paper=8x_and_~16"
                ),
            )
        )
    return rows
