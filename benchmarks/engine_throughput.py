"""Real-engine throughput: keys/second on this host for every strategy x op.

This is the TPU-native performance plane (jit'd JAX); on the CPU container
it measures real executed work, demonstrating the throughput ordering the
partitioning strategies produce outside the cycle model.

Rows come in four flavours per strategy: the jnp reference path for plain
lookups over every paper key set, the ordered-query ops (predecessor /
range_count / range_scan -- DESIGN.md §6) on the ``random`` set, (at a
smaller batch) the Pallas forest-kernel path (``use_kernel=True``), so the
bench trajectory tracks the kernel the TPU actually runs and not just the
oracle, and MIXED read/write streams (90/10 and 50/50) through
``BSTServer``'s delta write path (DESIGN.md §7) -- the rows CI publishes
to watch live-update serving throughput.  Interpret-mode kernel timings
measure executed semantics on CPU, not TPU performance (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import subprocess
import sys
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core.engine import BSTEngine, PAPER_CONFIGS
from repro.data.keysets import make_key_sets, make_tree_data
from repro.serving import BSTServer

# Ordered ops benchmarked per strategy (lookup is the baseline row family).
ORDERED_OPS = ("predecessor", "range_count", "range_scan")


def _time_op(eng: BSTEngine, op: str, q, q_hi, warmup=1, iters=3) -> float:
    if q_hi is None:
        return time_fn(lambda a: eng.query(op, a), q, warmup=warmup, iters=iters)
    return time_fn(
        lambda a, b: eng.query(op, a, b), q, q_hi, warmup=warmup, iters=iters
    )


def run(n_keys=(1 << 16) - 1, batch=16384, kernel_batch=2048, quick=False) -> List[Row]:
    # batch sized so the retired-driver baseline rows (hyb_kernel_vs_driver
    # below -- the one place the old O(B * n * capacity) direct dispatch
    # still runs, as the regression-gate baseline) finish in seconds;
    # keys/s is batch-size stable for the engines themselves.
    keys, values = make_tree_data(n_keys, seed=0)
    rows: List[Row] = []
    engines = {n: BSTEngine(keys, values, c) for n, c in PAPER_CONFIGS.items()}
    sets = make_key_sets(engines["Hrz"].tree, batch)
    for set_name, q in sets.items():
        for name, eng in engines.items():
            us = time_fn(eng.lookup, q, warmup=1, iters=3)
            rows.append(
                Row(
                    name=f"engine/{set_name}/{name}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Ordered-query ops (DESIGN.md §6) per strategy on the random set: one
    # descent per op (range ops descend lo||hi), so keys/s is comparable to
    # the lookup rows above.
    rng = np.random.default_rng(3)
    q = sets["random"]
    span = rng.integers(0, 4 * n_keys // batch + 2, size=batch).astype(np.int32)
    lo, hi = q, (q + span).astype(np.int32)
    for op in ORDERED_OPS:
        a, b = (lo, hi) if op.startswith("range") else (q, None)
        for name, eng in engines.items():
            us = _time_op(eng, op, a, b)
            rows.append(
                Row(
                    name=f"engine/random/{name}/{op}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Pallas forest-kernel path (interpret mode): smaller batch, one key set,
    # so the full matrix stays tractable on CPU while still exercising the
    # exact kernel datapath every strategy lowers to.  One ordered op rides
    # along per strategy (the same single pallas_call; see DESIGN.md §6).
    kq = sets["random"][:kernel_batch]
    klo, khi = lo[:kernel_batch], hi[:kernel_batch]
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, dataclasses.replace(cfg, use_kernel=True))
        us = time_fn(eng.lookup, kq, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )
        us = _time_op(eng, "range_count", klo, khi, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/range_count/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )

    rows.extend(hyb_kernel_vs_driver_rows(keys, values, batch=kernel_batch))
    rows.extend(mixed_rw_rows(keys, values, batch=min(batch, 8192)))
    # quick halves the chunk and trims stream/trials so CI's engine suite
    # stays quick (the 8192-row chunks still clear the gate's 4k floor).
    # The tree stays full-size on purpose: against a shallow tree the
    # per-chunk fixed costs drown the descent and the comparison measures
    # dispatch overhead, not serving.
    rows.extend(
        sharded_serve_rows(chunk=8192, n_chunks=6, trials=5)
        if quick
        else sharded_serve_rows()
    )
    return rows


def _retired_hyb_driver(tree, n_trees: int, mapping: str, slack: float = 2.0):
    """The RETIRED driver-level hyb composition, reconstructed from the
    shared phase functions (route -> jnp dispatch -> gather -> forest-kernel
    subtree descent -> combine -> jnp stall round).  It exists ONLY here,
    as the regression-gate baseline recorded in every BENCH_*.json run:
    the engine itself now lowers the whole pipeline through the single
    forest ``pallas_call`` (DESIGN.md §8), and CI fails if that in-kernel
    path ever drops below this composition's throughput.
    """
    split = int(math.log2(n_trees))
    idx = tree_lib.all_subtree_gather_indices(tree.height, split)
    fk, fv = tree.keys[jnp.asarray(idx)], tree.values[jnp.asarray(idx)]
    reg_n = (1 << max(split, 1)) - 1
    rk, rv = tree.keys[:reg_n], tree.values[:reg_n]
    sub_h = tree.height - split

    def run(queries):
        B = queries.shape[0]
        dest, reg_val, reg_found = plans_lib.route_phase(rk, rv, queries, split)
        capacity = int(math.ceil(B / n_trees * slack))
        dplan = plans_lib.dispatch_phase(
            mapping, dest, n_trees, capacity, active=~reg_found
        )
        per_q, per_act = plans_lib.gather_phase(queries, dplan)
        sub_v, sub_f = plans_lib.descend_phase(
            fk, fv, sub_h, per_q, per_act, use_kernel=True, interpret=True
        )
        val, found = plans_lib.combine_phase(
            sub_v, sub_f, dplan, B, reg_val, reg_found
        )

        def retry(args):
            val, found = args
            r_val, r_found = tree_lib.search_reference(tree, queries)
            return (
                jnp.where(dplan.overflow, r_val, val),
                jnp.where(dplan.overflow, r_found, found),
            )

        return jax.lax.cond(
            jnp.any(dplan.overflow), retry, lambda a: a, (val, found)
        )

    return jax.jit(run)


def hyb_kernel_vs_driver_rows(keys, values, batch: int) -> List[Row]:
    """Hyb in-kernel pipeline vs the retired driver composition, same run.

    Two rows per hyb preset, tagged ``pair=<name>``: ``hyb_kernel`` is the
    engine's real path (route + dispatch + descent + stall replay in ONE
    ``pallas_call``), ``hyb_driver`` the retired composition above.  CI's
    regression gate (scripts/check_bench.py) reads these pairs out of
    BENCH_4.json and fails when the kernel path is the slower one.
    """
    rng = np.random.default_rng(5)
    q = rng.choice(np.concatenate([keys, keys + 1]), batch).astype(np.int32)
    tree = tree_lib.build_tree(np.asarray(keys), np.asarray(values))
    rows: List[Row] = []
    for name, cfg in PAPER_CONFIGS.items():
        if cfg.strategy != "hyb":
            continue
        plan = plans_lib.make_plan(
            tree, strategy="hyb", n_trees=cfg.n_trees, mapping=cfg.mapping
        )
        ker = jax.jit(
            lambda qq, plan=plan: plans_lib.execute_plan(
                plan, qq, use_kernel=True, interpret=True
            )
        )
        drv = _retired_hyb_driver(tree, cfg.n_trees, cfg.mapping)
        qj = jnp.asarray(q)
        # both paths must agree before either is worth timing -- the gate
        # downstream assumes the rows measure equivalent work
        kv, kf = ker(qj)
        dv, df = drv(qj)
        bad = int(
            np.sum(np.asarray(kv) != np.asarray(dv))
            + np.sum(np.asarray(kf) != np.asarray(df))
        )
        if bad:
            raise RuntimeError(
                f"{name}: in-kernel hyb path disagrees with the retired "
                f"driver composition on {bad} lanes -- refusing to record "
                "a kernel-vs-driver pair for non-equivalent work"
            )
        for kind, fn in (("hyb_kernel", ker), ("hyb_driver", drv)):
            us = time_fn(fn, qj, warmup=1, iters=5)
            rows.append(
                Row(
                    name=f"engine/random/{name}/{kind}",
                    us_per_call=us,
                    derived=(
                        f"keys_per_sec={batch / (us / 1e6):.3e};"
                        f"batch={batch};pair={name}"
                    ),
                )
            )
    return rows


def mixed_rw_rows(keys, values, batch: int, rounds: int = 4) -> List[Row]:
    """Mixed read/write serving throughput through the delta write path.

    Each round submits an interleaved write batch + read batch to a
    ``BSTServer`` whose engine carries a delta buffer (DESIGN.md §7), then
    drains; ``keys_per_sec`` covers reads AND absorbed updates over
    engine-busy time, with compaction cost included whenever the stream
    trips the high-water mark.  One row per (mix, strategy).
    """
    rng = np.random.default_rng(7)
    rows: List[Row] = []
    for mix, write_frac in (("90_10", 0.10), ("50_50", 0.50)):
        for name in ("Hrz", "Dup8", "Hyb8q"):
            cfg = dataclasses.replace(PAPER_CONFIGS[name], delta_capacity=2048)
            srv = BSTServer(keys, values, cfg, chunk_size=batch)
            srv.warmup(("lookup",))
            # warm the (padded, fixed-shape) ingest program too
            srv.submit_write(np.int32(1), np.int32(1))
            srv.drain()
            srv.reset_stats()
            n_w = int(batch * write_frac)
            for _ in range(rounds):
                wk = rng.integers(1, 2**20, n_w).astype(np.int32)
                srv.submit_write(wk, wk)
                srv.submit(rng.choice(keys, batch - n_w).astype(np.int32))
                srv.drain()
            s = srv.stats
            rows.append(
                Row(
                    name=f"serve/mixed_{mix}/{name}",
                    us_per_call=s.busy_s / rounds * 1e6,  # one mixed round
                    derived=(
                        f"keys_per_sec={s.keys_per_sec:.3e};batch={batch};"
                        f"write_frac={write_frac};updates={s.updates};"
                        f"compactions={s.compactions}"
                    ),
                )
            )
    return rows


# The sharded serving comparison needs a multi-device host, and the XLA
# device-count flag must be set before jax initializes -- so the rows are
# measured in a subprocess (exactly like tests/test_distributed.py) and
# returned as JSON on the last stdout line.  Device count tracks the
# PHYSICAL core count: a host-simulated mesh wider than the cores measures
# oversubscription, not scaling.
_SHARDED_BENCH = r"""
import os, sys, json, time, statistics
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(devices)d"
sys.path.insert(0, %(src)r)
import numpy as np
from repro.core.engine import EngineConfig
from repro.core import distributed as D
from repro.data.keysets import make_tree_data
from repro.serving import BSTServer

DEV = %(devices)d
CHUNK = %(chunk)d
N_CHUNKS = %(n_chunks)d
TRIALS = %(trials)d
rng = np.random.default_rng(11)
keys, values = make_tree_data(%(n_keys)d, seed=0)
stream = rng.choice(keys, N_CHUNKS * CHUNK).astype(np.int32)
rows = []

def drain_stream(srv):
    srv.submit(stream)
    t0 = time.perf_counter()
    srv.drain()
    return time.perf_counter() - t0

for strategy in ("dup", "hrz", "hyb"):
    n_trees = max(2, DEV) if strategy != "hrz" else 1
    cfg = EngineConfig(strategy=strategy, n_trees=n_trees)
    mesh = D.make_serving_mesh(strategy)
    servers = {
        "single": BSTServer(keys, values, cfg, chunk_size=CHUNK),
        "sharded": BSTServer(keys, values, cfg, chunk_size=CHUNK, mesh=mesh),
    }
    for srv in servers.values():
        srv.warmup(("lookup",))
    # Interleaved A/B trials so host noise hits both modes alike; the row
    # records the per-mode MEDIAN drain wall (keys/sec over the stream).
    times = {name: [] for name in servers}
    for _ in range(TRIALS):
        for name, srv in servers.items():
            times[name].append(drain_stream(srv))
    # Per-device stored nodes: the capacity axis subtree sharding buys
    # (DESIGN.md §9) -- dup replicates (no win), hrz/hyb hold 1/M of the
    # tree plus the replicated register layer.  MEASURED from each
    # server's real shard layout, so a sharding regression (an operand
    # silently replicated) trips the gate instead of a formula hiding it.
    mem = {name: srv.memory_nodes_per_device() for name, srv in servers.items()}
    for name in servers:
        dt = statistics.median(times[name])
        rows.append({
            "name": "serve/sharded_%%s/%%s" %% (strategy, name),
            "us_per_call": dt * 1e6,
            "derived": ";".join([
                "spair=%%s" %% strategy,
                "mode=%%s" %% name,
                "keys_per_sec=%%.3e" %% (stream.size / dt),
                "batch=%%d" %% CHUNK,
                "devices=%%d" %% DEV,
                "mem_nodes_dev=%%d" %% mem[name],
            ]),
        })

# One sharded mixed read/write row: the delta buffer riding the sharded
# program as replicated operands, compactions included (DESIGN.md §9).
cfg = EngineConfig(strategy="dup", n_trees=max(2, DEV), delta_capacity=2048)
srv = BSTServer(keys, values, cfg, chunk_size=CHUNK, mesh=D.make_serving_mesh("dup"))
srv.warmup(("lookup",))
srv.submit_write(np.int32(1), np.int32(1))
srv.drain()
srv.reset_stats()
n_w = CHUNK // 10
t0 = time.perf_counter()
for _ in range(4):
    wk = rng.integers(1, 2**20, n_w).astype(np.int32)
    srv.submit_write(wk, wk)
    srv.submit(rng.choice(keys, CHUNK - n_w).astype(np.int32))
    srv.drain()
dt = time.perf_counter() - t0
s = srv.stats
rows.append({
    "name": "serve/sharded_mixed_90_10/dup",
    "us_per_call": dt / 4 * 1e6,
    "derived": ";".join([
        "keys_per_sec=%%.3e" %% (s.served / dt),
        "batch=%%d" %% CHUNK,
        "devices=%%d" %% DEV,
        "write_frac=0.10",
        "updates=%%d" %% s.updates,
        "compactions=%%d" %% s.compactions,
    ]),
})
print("ROWS_JSON:" + json.dumps(rows))
"""


def sharded_serve_rows(
    chunk: int = 16384,
    n_chunks: int = 8,
    trials: int = 7,
    n_keys: int = (1 << 16) - 1,
) -> List[Row]:
    """Sharded vs single-chip serving, same run, forced multi-device host.

    Two rows per strategy (``serve/sharded_<strategy>/{sharded,single}``,
    tagged ``spair=<strategy>``) plus one sharded mixed read/write row.
    scripts/check_bench.py gates each strategy on ITS scaling axis: dup
    (replicate-and-split, the throughput play) must serve at least as many
    keys/sec as the single-chip server; hrz/hyb (subtree sharding, the
    capacity play) must store strictly fewer nodes per device
    (``mem_nodes_dev``) -- the deterministic figure a host-simulated mesh
    can gate without CPU timing noise.
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # Largest power of two in [2, 8] that fits the cores: subtree sharding
    # needs a power-of-two mesh axis (and any such count divides the
    # power-of-two chunk), so a 6-core host measures a 4-device mesh.
    devices = 1 << int(math.log2(max(2, min(8, os.cpu_count() or 2))))
    code = _SHARDED_BENCH % {
        "devices": devices,
        "src": os.path.join(root, "src"),
        "chunk": chunk,
        "n_chunks": n_chunks,
        "trials": trials,
        "n_keys": n_keys,
    }
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=1800
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded bench subprocess failed:\nSTDOUT:\n{out.stdout}\n"
            f"STDERR:\n{out.stderr}"
        )
    payload = [
        line for line in out.stdout.splitlines() if line.startswith("ROWS_JSON:")
    ]
    if not payload:
        raise RuntimeError(f"sharded bench emitted no rows:\n{out.stdout}")
    return [Row(**r) for r in json.loads(payload[-1][len("ROWS_JSON:"):])]
