"""Real-engine throughput: keys/second on this host for every strategy.

This is the TPU-native performance plane (jit'd JAX); on the CPU container
it measures real executed work, demonstrating the throughput ordering the
partitioning strategies produce outside the cycle model.

Rows come in two flavours per strategy: the jnp reference path and (for the
``random`` key set, at a smaller batch) the Pallas forest-kernel path
(``use_kernel=True``), so the bench trajectory tracks the kernel the TPU
actually runs and not just the oracle.  Interpret-mode kernel timings
measure executed semantics on CPU, not TPU performance (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from benchmarks.common import Row, time_fn
from repro.core.engine import BSTEngine, PAPER_CONFIGS
from repro.data.keysets import make_key_sets, make_tree_data


def run(n_keys=(1 << 16) - 1, batch=16384, kernel_batch=2048) -> List[Row]:
    # batch sized so the direct-mapped engines (whose stateless dispatch is
    # deliberately faithful-but-slow on CPU; see DESIGN.md §2) finish in
    # seconds -- keys/s is batch-size stable for the others.
    keys, values = make_tree_data(n_keys, seed=0)
    rows: List[Row] = []
    engines = {n: BSTEngine(keys, values, c) for n, c in PAPER_CONFIGS.items()}
    sets = make_key_sets(engines["Hrz"].tree, batch)
    for set_name, q in sets.items():
        for name, eng in engines.items():
            us = time_fn(eng.lookup, q, warmup=1, iters=3)
            rows.append(
                Row(
                    name=f"engine/{set_name}/{name}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Pallas forest-kernel path (interpret mode): smaller batch, one key set,
    # so the full matrix stays tractable on CPU while still exercising the
    # exact kernel datapath every strategy lowers to.
    kq = sets["random"][:kernel_batch]
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, dataclasses.replace(cfg, use_kernel=True))
        us = time_fn(eng.lookup, kq, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )
    return rows
