"""Real-engine throughput: keys/second on this host for every strategy x op.

This is the TPU-native performance plane (jit'd JAX); on the CPU container
it measures real executed work, demonstrating the throughput ordering the
partitioning strategies produce outside the cycle model.

Rows come in four flavours per strategy: the jnp reference path for plain
lookups over every paper key set, the ordered-query ops (predecessor /
range_count / range_scan -- DESIGN.md §6) on the ``random`` set, (at a
smaller batch) the Pallas forest-kernel path (``use_kernel=True``), so the
bench trajectory tracks the kernel the TPU actually runs and not just the
oracle, and MIXED read/write streams (90/10 and 50/50) through
``BSTServer``'s delta write path (DESIGN.md §7) -- the rows CI publishes
to watch live-update serving throughput.  Interpret-mode kernel timings
measure executed semantics on CPU, not TPU performance (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import plans as plans_lib
from repro.core import tree as tree_lib
from repro.core.engine import BSTEngine, PAPER_CONFIGS
from repro.data.keysets import make_key_sets, make_tree_data
from repro.serving import BSTServer

# Ordered ops benchmarked per strategy (lookup is the baseline row family).
ORDERED_OPS = ("predecessor", "range_count", "range_scan")


def _time_op(eng: BSTEngine, op: str, q, q_hi, warmup=1, iters=3) -> float:
    if q_hi is None:
        return time_fn(lambda a: eng.query(op, a), q, warmup=warmup, iters=iters)
    return time_fn(
        lambda a, b: eng.query(op, a, b), q, q_hi, warmup=warmup, iters=iters
    )


def run(n_keys=(1 << 16) - 1, batch=16384, kernel_batch=2048) -> List[Row]:
    # batch sized so the retired-driver baseline rows (hyb_kernel_vs_driver
    # below -- the one place the old O(B * n * capacity) direct dispatch
    # still runs, as the regression-gate baseline) finish in seconds;
    # keys/s is batch-size stable for the engines themselves.
    keys, values = make_tree_data(n_keys, seed=0)
    rows: List[Row] = []
    engines = {n: BSTEngine(keys, values, c) for n, c in PAPER_CONFIGS.items()}
    sets = make_key_sets(engines["Hrz"].tree, batch)
    for set_name, q in sets.items():
        for name, eng in engines.items():
            us = time_fn(eng.lookup, q, warmup=1, iters=3)
            rows.append(
                Row(
                    name=f"engine/{set_name}/{name}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Ordered-query ops (DESIGN.md §6) per strategy on the random set: one
    # descent per op (range ops descend lo||hi), so keys/s is comparable to
    # the lookup rows above.
    rng = np.random.default_rng(3)
    q = sets["random"]
    span = rng.integers(0, 4 * n_keys // batch + 2, size=batch).astype(np.int32)
    lo, hi = q, (q + span).astype(np.int32)
    for op in ORDERED_OPS:
        a, b = (lo, hi) if op.startswith("range") else (q, None)
        for name, eng in engines.items():
            us = _time_op(eng, op, a, b)
            rows.append(
                Row(
                    name=f"engine/random/{name}/{op}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Pallas forest-kernel path (interpret mode): smaller batch, one key set,
    # so the full matrix stays tractable on CPU while still exercising the
    # exact kernel datapath every strategy lowers to.  One ordered op rides
    # along per strategy (the same single pallas_call; see DESIGN.md §6).
    kq = sets["random"][:kernel_batch]
    klo, khi = lo[:kernel_batch], hi[:kernel_batch]
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, dataclasses.replace(cfg, use_kernel=True))
        us = time_fn(eng.lookup, kq, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )
        us = _time_op(eng, "range_count", klo, khi, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/range_count/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )

    rows.extend(hyb_kernel_vs_driver_rows(keys, values, batch=kernel_batch))
    rows.extend(mixed_rw_rows(keys, values, batch=min(batch, 8192)))
    return rows


def _retired_hyb_driver(tree, n_trees: int, mapping: str, slack: float = 2.0):
    """The RETIRED driver-level hyb composition, reconstructed from the
    shared phase functions (route -> jnp dispatch -> gather -> forest-kernel
    subtree descent -> combine -> jnp stall round).  It exists ONLY here,
    as the regression-gate baseline recorded in every BENCH_*.json run:
    the engine itself now lowers the whole pipeline through the single
    forest ``pallas_call`` (DESIGN.md §8), and CI fails if that in-kernel
    path ever drops below this composition's throughput.
    """
    split = int(math.log2(n_trees))
    idx = tree_lib.all_subtree_gather_indices(tree.height, split)
    fk, fv = tree.keys[jnp.asarray(idx)], tree.values[jnp.asarray(idx)]
    reg_n = (1 << max(split, 1)) - 1
    rk, rv = tree.keys[:reg_n], tree.values[:reg_n]
    sub_h = tree.height - split

    def run(queries):
        B = queries.shape[0]
        dest, reg_val, reg_found = plans_lib.route_phase(rk, rv, queries, split)
        capacity = int(math.ceil(B / n_trees * slack))
        dplan = plans_lib.dispatch_phase(
            mapping, dest, n_trees, capacity, active=~reg_found
        )
        per_q, per_act = plans_lib.gather_phase(queries, dplan)
        sub_v, sub_f = plans_lib.descend_phase(
            fk, fv, sub_h, per_q, per_act, use_kernel=True, interpret=True
        )
        val, found = plans_lib.combine_phase(
            sub_v, sub_f, dplan, B, reg_val, reg_found
        )

        def retry(args):
            val, found = args
            r_val, r_found = tree_lib.search_reference(tree, queries)
            return (
                jnp.where(dplan.overflow, r_val, val),
                jnp.where(dplan.overflow, r_found, found),
            )

        return jax.lax.cond(
            jnp.any(dplan.overflow), retry, lambda a: a, (val, found)
        )

    return jax.jit(run)


def hyb_kernel_vs_driver_rows(keys, values, batch: int) -> List[Row]:
    """Hyb in-kernel pipeline vs the retired driver composition, same run.

    Two rows per hyb preset, tagged ``pair=<name>``: ``hyb_kernel`` is the
    engine's real path (route + dispatch + descent + stall replay in ONE
    ``pallas_call``), ``hyb_driver`` the retired composition above.  CI's
    regression gate (scripts/check_bench.py) reads these pairs out of
    BENCH_4.json and fails when the kernel path is the slower one.
    """
    rng = np.random.default_rng(5)
    q = rng.choice(np.concatenate([keys, keys + 1]), batch).astype(np.int32)
    tree = tree_lib.build_tree(np.asarray(keys), np.asarray(values))
    rows: List[Row] = []
    for name, cfg in PAPER_CONFIGS.items():
        if cfg.strategy != "hyb":
            continue
        plan = plans_lib.make_plan(
            tree, strategy="hyb", n_trees=cfg.n_trees, mapping=cfg.mapping
        )
        ker = jax.jit(
            lambda qq, plan=plan: plans_lib.execute_plan(
                plan, qq, use_kernel=True, interpret=True
            )
        )
        drv = _retired_hyb_driver(tree, cfg.n_trees, cfg.mapping)
        qj = jnp.asarray(q)
        # both paths must agree before either is worth timing -- the gate
        # downstream assumes the rows measure equivalent work
        kv, kf = ker(qj)
        dv, df = drv(qj)
        bad = int(
            np.sum(np.asarray(kv) != np.asarray(dv))
            + np.sum(np.asarray(kf) != np.asarray(df))
        )
        if bad:
            raise RuntimeError(
                f"{name}: in-kernel hyb path disagrees with the retired "
                f"driver composition on {bad} lanes -- refusing to record "
                "a kernel-vs-driver pair for non-equivalent work"
            )
        for kind, fn in (("hyb_kernel", ker), ("hyb_driver", drv)):
            us = time_fn(fn, qj, warmup=1, iters=5)
            rows.append(
                Row(
                    name=f"engine/random/{name}/{kind}",
                    us_per_call=us,
                    derived=(
                        f"keys_per_sec={batch / (us / 1e6):.3e};"
                        f"batch={batch};pair={name}"
                    ),
                )
            )
    return rows


def mixed_rw_rows(keys, values, batch: int, rounds: int = 4) -> List[Row]:
    """Mixed read/write serving throughput through the delta write path.

    Each round submits an interleaved write batch + read batch to a
    ``BSTServer`` whose engine carries a delta buffer (DESIGN.md §7), then
    drains; ``keys_per_sec`` covers reads AND absorbed updates over
    engine-busy time, with compaction cost included whenever the stream
    trips the high-water mark.  One row per (mix, strategy).
    """
    rng = np.random.default_rng(7)
    rows: List[Row] = []
    for mix, write_frac in (("90_10", 0.10), ("50_50", 0.50)):
        for name in ("Hrz", "Dup8", "Hyb8q"):
            cfg = dataclasses.replace(PAPER_CONFIGS[name], delta_capacity=2048)
            srv = BSTServer(keys, values, cfg, chunk_size=batch)
            srv.warmup(("lookup",))
            # warm the (padded, fixed-shape) ingest program too
            srv.submit_write(np.int32(1), np.int32(1))
            srv.drain()
            srv.reset_stats()
            n_w = int(batch * write_frac)
            for _ in range(rounds):
                wk = rng.integers(1, 2**20, n_w).astype(np.int32)
                srv.submit_write(wk, wk)
                srv.submit(rng.choice(keys, batch - n_w).astype(np.int32))
                srv.drain()
            s = srv.stats
            rows.append(
                Row(
                    name=f"serve/mixed_{mix}/{name}",
                    us_per_call=s.busy_s / rounds * 1e6,  # one mixed round
                    derived=(
                        f"keys_per_sec={s.keys_per_sec:.3e};batch={batch};"
                        f"write_frac={write_frac};updates={s.updates};"
                        f"compactions={s.compactions}"
                    ),
                )
            )
    return rows
