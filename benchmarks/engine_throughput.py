"""Real-engine throughput: keys/second on this host for every strategy x op.

This is the TPU-native performance plane (jit'd JAX); on the CPU container
it measures real executed work, demonstrating the throughput ordering the
partitioning strategies produce outside the cycle model.

Rows come in three flavours per strategy: the jnp reference path for plain
lookups over every paper key set, the ordered-query ops (predecessor /
range_count / range_scan -- DESIGN.md §6) on the ``random`` set, and (at a
smaller batch) the Pallas forest-kernel path (``use_kernel=True``), so the
bench trajectory tracks the kernel the TPU actually runs and not just the
oracle.  Interpret-mode kernel timings measure executed semantics on CPU,
not TPU performance (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from benchmarks.common import Row, time_fn
from repro.core.engine import BSTEngine, PAPER_CONFIGS
from repro.data.keysets import make_key_sets, make_tree_data

# Ordered ops benchmarked per strategy (lookup is the baseline row family).
ORDERED_OPS = ("predecessor", "range_count", "range_scan")


def _time_op(eng: BSTEngine, op: str, q, q_hi, warmup=1, iters=3) -> float:
    if q_hi is None:
        return time_fn(lambda a: eng.query(op, a), q, warmup=warmup, iters=iters)
    return time_fn(
        lambda a, b: eng.query(op, a, b), q, q_hi, warmup=warmup, iters=iters
    )


def run(n_keys=(1 << 16) - 1, batch=16384, kernel_batch=2048) -> List[Row]:
    # batch sized so the direct-mapped engines (whose stateless dispatch is
    # deliberately faithful-but-slow on CPU; see DESIGN.md §2) finish in
    # seconds -- keys/s is batch-size stable for the others.
    keys, values = make_tree_data(n_keys, seed=0)
    rows: List[Row] = []
    engines = {n: BSTEngine(keys, values, c) for n, c in PAPER_CONFIGS.items()}
    sets = make_key_sets(engines["Hrz"].tree, batch)
    for set_name, q in sets.items():
        for name, eng in engines.items():
            us = time_fn(eng.lookup, q, warmup=1, iters=3)
            rows.append(
                Row(
                    name=f"engine/{set_name}/{name}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Ordered-query ops (DESIGN.md §6) per strategy on the random set: one
    # descent per op (range ops descend lo||hi), so keys/s is comparable to
    # the lookup rows above.
    rng = np.random.default_rng(3)
    q = sets["random"]
    span = rng.integers(0, 4 * n_keys // batch + 2, size=batch).astype(np.int32)
    lo, hi = q, (q + span).astype(np.int32)
    for op in ORDERED_OPS:
        a, b = (lo, hi) if op.startswith("range") else (q, None)
        for name, eng in engines.items():
            us = _time_op(eng, op, a, b)
            rows.append(
                Row(
                    name=f"engine/random/{name}/{op}",
                    us_per_call=us,
                    derived=f"keys_per_sec={batch / (us / 1e6):.3e};batch={batch}",
                )
            )

    # Pallas forest-kernel path (interpret mode): smaller batch, one key set,
    # so the full matrix stays tractable on CPU while still exercising the
    # exact kernel datapath every strategy lowers to.  One ordered op rides
    # along per strategy (the same single pallas_call; see DESIGN.md §6).
    kq = sets["random"][:kernel_batch]
    klo, khi = lo[:kernel_batch], hi[:kernel_batch]
    for name, cfg in PAPER_CONFIGS.items():
        eng = BSTEngine(keys, values, dataclasses.replace(cfg, use_kernel=True))
        us = time_fn(eng.lookup, kq, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )
        us = _time_op(eng, "range_count", klo, khi, warmup=1, iters=2)
        rows.append(
            Row(
                name=f"engine/random/{name}/range_count/kernel",
                us_per_call=us,
                derived=(
                    f"keys_per_sec={kernel_batch / (us / 1e6):.3e};"
                    f"batch={kernel_batch};use_kernel=1"
                ),
            )
        )
    return rows
