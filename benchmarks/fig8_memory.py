"""Paper Fig. 8: memory / resource utilization relative to Hrz.

Memory = stored tree nodes (exact, from the engine).  LUT/logic proxies are
modeled per the paper's qualitative findings and labeled as such: the queue
mapping needs the labeling network + read/write pointers (more logic), the
direct mapping is the cheapest router.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.engine import BSTEngine, PAPER_CONFIGS
from repro.data.keysets import make_tree_data

# Modeled router-logic cost per searched key slot, normalized to Hrz = 1.0.
# (FPGA LUT counts have no TPU analogue; see DESIGN.md §2 "what does NOT
# transfer".  Constants chosen to reproduce the paper's qualitative ordering
# Hrz < Dup < Hyb-direct < Hyb-queue.)
LOGIC_PROXY = {
    "Hrz": 1.0,
    "Dup4": 4.0,
    "Dup8": 8.0,
    "Hyb4": 4.6,
    "Hyb4q": 6.0,
    "Hyb8": 9.2,
    "Hyb8q": 12.0,
}


def run() -> List[Row]:
    keys, values = make_tree_data((1 << 14) - 1, seed=0)
    engines = {n: BSTEngine(keys, values, c) for n, c in PAPER_CONFIGS.items()}
    base = engines["Hrz"].memory_nodes()
    rows = []
    for name, eng in engines.items():
        rows.append(
            Row(
                name=f"fig8/{name}",
                us_per_call=0.0,
                derived=(
                    f"memory_nodes={eng.memory_nodes()};"
                    f"memory_vs_hrz={eng.memory_nodes() / base:.2f};"
                    f"logic_proxy_vs_hrz={LOGIC_PROXY[name]:.1f}"
                ),
            )
        )
    return rows
