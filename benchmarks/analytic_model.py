"""Analytic FLOPs / HBM-bytes model per (arch x shape) cell.

Why analytic: XLA's cost_analysis counts while-loop bodies ONCE (verified in
this container -- see EXPERIMENTS.md §Dry-run), so scanned-layer modules
under-report by ~L x.  The roofline therefore uses an auditable per-matmul
analytic model, cross-validated against HLO-exact flops on small UNROLLED
configs (tests/test_roofline_model.py), with HLO used exactly where it is
exact: per-device memory images and collective bytes (loop-multiplied).

All counts are GLOBAL per step; divide by chip count for per-device terms.
Conventions: MAC = 2 flops; causal attention halves the score work;
backward = 2x forward; full remat re-runs the forward (+1x) during backward.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class CellCost:
    flops: float  # global FLOPs per step
    hbm_bytes: float  # global HBM traffic per step (lower bound)
    model_flops: float  # 6*N_active*tokens (train) / 2*N_active*tokens (fwd)
    detail: Dict[str, float]


def _attn_flops_per_layer(cfg: ModelConfig, B: int, S: int, kv_len: int = None):
    """QK^T + PV for one layer, causal, optional sliding window."""
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    kv_len = kv_len if kv_len is not None else S
    eff = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    if S == kv_len:  # square causal: ~half the block is live
        pairs = B * H * S * eff * (0.5 if eff == S else 1.0)
    else:
        pairs = B * H * S * eff
    return 2 * 2 * pairs * hd  # two matmuls, MAC=2


def _proj_flops_per_layer(cfg: ModelConfig, tokens: int):
    """Matmul params touched per token, x2 flops (excludes attention pairs)."""
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    p = 0
    if cfg.has_attention:
        p += D * H * hd + 2 * D * KV * hd + H * hd * D
    if cfg.family == "moe":
        p += D * cfg.n_experts  # router
        p += cfg.top_k * 3 * D * F  # active experts only
    elif F > 0:
        p += 3 * D * F
    if cfg.family in ("ssm", "hybrid"):
        di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        p += 2 * D * di + 2 * D * N + D * Hs + di * D
    return 2 * p * tokens


def _ssd_flops_per_layer(cfg: ModelConfig, B: int, S: int):
    """Chunked SSD core (intra scores, inter state) -- see models/ssm.py."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    Q = min(cfg.ssm_chunk, S)
    N, H, P = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    per_token = 2 * Q * N + 2 * Q * H * P + 8 * H * P * N
    return B * S * per_token


def _head_flops(cfg: ModelConfig, tokens: int):
    return 2 * tokens * cfg.vocab_size * cfg.d_model


def cell_cost(cfg: ModelConfig, shape: ShapeConfig) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    bytes_per_param = 2  # bf16 compute copy

    if shape.kind == "train":
        tokens = B * S
        fwd = (
            L * (_proj_flops_per_layer(cfg, tokens)
                 + (_attn_flops_per_layer(cfg, B, S) if cfg.has_attention else 0)
                 + _ssd_flops_per_layer(cfg, B, S))
            + _head_flops(cfg, tokens)
        )
        if cfg.family == "encdec":
            fwd += cfg.encoder_layers * (
                _proj_flops_per_layer(cfg, tokens)
                + 2 * _attn_flops_per_layer(cfg, B, S)  # bidirectional
            ) + L * (  # cross attention per decoder layer
                2 * (cfg.d_model * cfg.n_heads * cfg.resolved_head_dim
                     + 2 * cfg.d_model * cfg.n_kv_heads * cfg.resolved_head_dim
                     + cfg.n_heads * cfg.resolved_head_dim * cfg.d_model) * tokens / 2
                + 2 * _attn_flops_per_layer(cfg, B, S, kv_len=S)
            )
        remat_extra = fwd if cfg.remat else 0.0
        flops = 3 * fwd + remat_extra  # fwd + 2x bwd + remat re-forward
        n_act = cfg.n_active_params()
        # HBM: params(bf16 r) + grads(f32 rw) + adam master/mu/nu(f32 rw) +
        # bf16 write-back + layer-boundary activations (bf16 w+r, x2 remat)
        n_par = cfg.n_params()
        param_opt = n_par * (2 + 8 + 24 + 2)
        acts = (L + (cfg.encoder_layers or 0)) * tokens * cfg.d_model * 2 * 4
        hbm = param_opt + acts
        return CellCost(flops, hbm, 6.0 * n_act * tokens,
                        {"fwd": fwd, "remat": remat_extra})

    if shape.kind == "prefill":
        tokens = B * S
        flops = (
            L * (_proj_flops_per_layer(cfg, tokens)
                 + (_attn_flops_per_layer(cfg, B, S) if cfg.has_attention else 0)
                 + _ssd_flops_per_layer(cfg, B, S))
            + _head_flops(cfg, B)  # last position only
        )
        if cfg.family == "encdec":
            flops += cfg.encoder_layers * (
                _proj_flops_per_layer(cfg, tokens)
                + 2 * _attn_flops_per_layer(cfg, B, S)
            )
        n_par = cfg.n_params()
        acts = L * tokens * cfg.d_model * 2 * 2
        kv_write = (
            2 * L * B * min(S, cfg.sliding_window or S)
            * cfg.n_kv_heads * cfg.resolved_head_dim * 2
            if cfg.has_attention else 0
        )
        hbm = n_par * 2 + acts + kv_write
        return CellCost(flops, hbm, 2.0 * cfg.n_active_params() * tokens, {})

    # decode: one token per sequence against a seq_len-deep cache
    tokens = B
    kv_len = S
    flops = (
        L * (_proj_flops_per_layer(cfg, tokens)
             + (_attn_flops_per_layer(cfg, B, 1, kv_len=kv_len)
                if cfg.has_attention else 0)
             + (B * (2 * cfg.ssm_state * cfg.ssm_heads * cfg.ssm_head_dim * 3)
                if cfg.family in ("ssm", "hybrid") else 0))
        + _head_flops(cfg, tokens)
    )
    if cfg.family == "encdec":
        flops += L * 2 * _attn_flops_per_layer(cfg, B, 1, kv_len=kv_len)
    n_act = cfg.n_active_params()
    kv_eff = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    kv_read = (
        2 * L * B * kv_eff * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        if cfg.has_attention else 0
    )
    if cfg.family == "encdec":
        kv_read *= 2  # self + cross memory
    ssm_state = (
        L * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2
        if cfg.family in ("ssm", "hybrid") else 0
    )
    hbm = n_act * 2 + kv_read + ssm_state
    return CellCost(flops, hbm, 2.0 * n_act * tokens, {"kv_read": kv_read})
