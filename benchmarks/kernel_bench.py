"""Pallas kernel microbenchmarks (interpret mode) vs jnp oracles.

Interpret-mode timings measure the *semantics* executed on CPU, not TPU
performance; the derived field carries the shapes so real-TPU reruns slot
into the same harness.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import tree as T
from repro.data.keysets import make_tree_data
from repro.kernels import ops

def run() -> List[Row]:
    rows: List[Row] = []

    # bst_search: 64K-node tree, 8K query chunk
    keys, values = make_tree_data((1 << 16) - 1, seed=0)
    tree = T.build_tree(keys, values)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.choice(keys, 8192).astype(np.int32))
    for use_ref in (True, False):
        us = time_fn(
            lambda q: ops.bst_search(
                tree.keys, tree.values, q, height=tree.height, use_ref=use_ref
            ),
            q, warmup=1, iters=3,
        )
        rows.append(
            Row(
                name=f"kernel/bst_search/{'ref' if use_ref else 'pallas_interpret'}",
                us_per_call=us,
                derived=f"keys_per_sec={8192 / (us / 1e6):.3e};tree_nodes={tree.n_nodes}",
            )
        )

    # queue_dispatch: 4K chunk over 16 destinations
    dest = jnp.asarray(rng.integers(0, 16, 4096).astype(np.int32))
    for use_ref in (True, False):
        us = time_fn(
            lambda d: ops.queue_dispatch(d, n_dest=16, capacity=512, use_ref=use_ref),
            dest, warmup=1, iters=3,
        )
        rows.append(
            Row(
                name=f"kernel/queue_dispatch/{'ref' if use_ref else 'pallas_interpret'}",
                us_per_call=us,
                derived="chunk=4096;n_dest=16;capacity=512",
            )
        )

    # flash_attention: 1k sequence, GQA 8->2 heads
    kq = jax.random.normal(jax.random.key(0), (8, 1024, 64), jnp.float32)
    kk = jax.random.normal(jax.random.key(1), (2, 1024, 64), jnp.float32)
    kv = jax.random.normal(jax.random.key(2), (2, 1024, 64), jnp.float32)
    for use_ref in (True, False):
        us = time_fn(
            lambda a, b, c: ops.flash_attention(a, b, c, causal=True, use_ref=use_ref),
            kq, kk, kv, warmup=1, iters=3,
        )
        flops = 2 * 8 * 1024 * 1024 * 64 * 2 / 2  # causal half
        rows.append(
            Row(
                name=f"kernel/flash_attention/{'ref' if use_ref else 'pallas_interpret'}",
                us_per_call=us,
                derived=f"gflops_effective={flops / (us / 1e6) / 1e9:.2f};BH=8;S=1024;d=64",
            )
        )
    return rows
