"""Shared benchmark utilities: timing + CSV row protocol.

Every benchmark module exposes ``run() -> list[Row]``; benchmarks/run.py
prints them as ``name,us_per_call,derived`` CSV (one per paper table/figure).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str  # free-form "key=value;key=value" payload

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
